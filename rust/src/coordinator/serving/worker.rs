//! The per-worker serving loop: pop → route → batch (one model) → pad →
//! execute → scatter.
//!
//! Each worker thread owns one instance of *every* registered model (a
//! [`ModelSet`]), kept in sync with the [`ModelRegistry`] through its
//! generation counter, and pulls from the shared [`RequestQueue`]. It
//! *dynamically batches per model*: block for the first live request, let
//! that request's model claim pick the flush target, then drain greedily —
//! waiting at most `max_wait` for stragglers **of the same model**
//! ([`RequestQueue::pop_model_or_steal`]) — up to that model's batch size.
//! A flush therefore never mixes models, and other models' requests keep
//! their queue positions while a batch forms.
//!
//! **Work stealing.** The straggler wait is not unconditional: if the
//! flush model's backlog is empty while *another* model has queued work,
//! the queue answers the straggler pop with a steal hint
//! ([`ModelPop::Steal`]) — there are no stragglers to wait for, so the
//! worker flushes the partial batch immediately and its next pop takes
//! the other model's backlog, instead of idling out `max_wait` while that
//! backlog sits behind a busy peer. Steals are counted per worker in
//! [`ServingMetrics`] (the never-co-flush-models invariant is untouched:
//! the stolen backlog forms its own single-model batch).
//!
//! Deadline enforcement happens twice: at pop time (an expired request
//! never occupies a batch slot) and again immediately before the flush —
//! the straggler window (`max_wait`) can outlive a short deadline, and a
//! request that expired while sitting in `pending` must be answered with
//! [`ServeError::DeadlineExceeded`], not executed late. Sample width is
//! also re-validated at flush time: a width-mismatched request that
//! reaches the queue through any future submit path gets a typed
//! [`ServeError::WrongInputWidth`] instead of panicking the worker on
//! `copy_from_slice`.
//!
//! Metrics record *real* occupancy per flush (`live.len()` of `batch`
//! slots), per worker *and* per model, so padded partial batches are
//! visible in the stats instead of silently inflating throughput.

use super::backend::BatchModel;
use super::queue::{ModelPop, QueuedRequest, RequestQueue, RouteTag};
use super::registry::ModelRegistry;
use super::ServeError;
use crate::coordinator::metrics::ServingMetrics;
use crate::kernels::plan::PlanCache;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Everything a worker thread needs besides its models. Doubles as the
/// worker's liveness guard: it is dropped when the worker exits — normal
/// shutdown, factory failure, *or panic unwind* — and the last drop closes
/// the queue and fails every still-queued request with
/// [`ServeError::Stopped`], so a pool whose workers have all died rejects
/// clients fast instead of letting them block on receivers forever.
pub(crate) struct WorkerContext {
    pub id: usize,
    pub queue: Arc<RequestQueue>,
    pub metrics: Arc<ServingMetrics>,
    pub registry: Arc<ModelRegistry>,
    /// Max time to wait for stragglers after the first request of a batch.
    pub max_wait: Duration,
    /// Drift re-tune trigger: when a model's achieved/tuned throughput
    /// ratio ([`BatchModel::drift`]) falls below this, an *idle* worker
    /// re-runs its schedule search and swaps plans. `None` disables.
    pub retune_threshold: Option<f64>,
    /// Count of workers still alive (shared across the pool).
    pub live: Arc<AtomicUsize>,
}

impl Drop for WorkerContext {
    fn drop(&mut self) {
        if self.live.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.queue.close_and_fail_pending();
        }
    }
}

/// What one worker reports back on its readiness channel: the default
/// model's geometry (the constructor checks all workers agree) plus its
/// structure namespaces and plan cache, which fill the default registry
/// entry before the constructor returns.
pub(crate) struct ReadyReport {
    pub batch: usize,
    pub in_dim: usize,
    pub classes: usize,
    pub structures: Vec<u64>,
    pub cache: Option<Arc<PlanCache>>,
}

/// One worker-resident model instance plus its padded batch buffer.
struct WorkerModel {
    model: Box<dyn BatchModel>,
    x: Vec<f32>,
    /// The registry re-tune epoch this instance's plans reflect. A lag
    /// behind the entry's counter means a pool peer completed a drift
    /// re-tune: this worker refreshes its detached plans from the shared
    /// cache instead of running (and double-counting) the search itself.
    retune_epoch: usize,
}

impl WorkerModel {
    fn new(model: Box<dyn BatchModel>) -> WorkerModel {
        let len = model.batch() * model.in_dim();
        WorkerModel {
            model,
            x: vec![0.0; len],
            retune_epoch: 0,
        }
    }
}

/// This worker's mirror of the registry: one instance per registered
/// model, built on this thread (some backends are not `Send`). A model
/// whose factory failed *after startup* is held as the error message and
/// answers its requests with [`ServeError::Backend`] instead of taking
/// the worker down.
#[derive(Default)]
pub(crate) struct ModelSet {
    models: HashMap<String, Result<WorkerModel, String>>,
    generation: usize,
}

impl ModelSet {
    /// Startup build: instantiate every registered model, failing the
    /// whole worker (and therefore server startup) on the first factory
    /// error. Returns the default model's readiness report.
    pub fn build_initial(&mut self, registry: &ModelRegistry) -> anyhow::Result<ReadyReport> {
        self.generation = registry.generation();
        let mut report = None;
        for entry in registry.snapshot() {
            let model = (entry.factory)()?;
            if entry.id == registry.default_id() {
                report = Some(ReadyReport {
                    batch: model.batch(),
                    in_dim: model.in_dim(),
                    classes: model.classes(),
                    structures: model.structures(),
                    cache: model.plan_cache(),
                });
            }
            let mut wm = WorkerModel::new(model);
            wm.retune_epoch = entry.retune_epoch();
            self.models.insert(entry.id.clone(), Ok(wm));
        }
        report.ok_or_else(|| anyhow::anyhow!("default model is not registered at startup"))
    }

    /// Mirror the registry after a register/unregister: drop instances of
    /// removed models, build instances of new ones (keeping retired-but-
    /// draining entries resident so their queued requests are still
    /// served). Build failures degrade to per-model errors — post-startup,
    /// one bad factory must not kill a worker serving other models.
    fn sync(&mut self, registry: &ModelRegistry) {
        let generation = registry.generation();
        if generation == self.generation {
            return;
        }
        self.generation = generation;
        let entries = registry.snapshot();
        let live: HashSet<&str> = entries.iter().map(|e| e.id.as_str()).collect();
        self.models.retain(|id, _| live.contains(id.as_str()));
        for entry in &entries {
            if self.models.contains_key(&entry.id) {
                continue;
            }
            let built = (entry.factory)()
                .map(|m| {
                    let mut wm = WorkerModel::new(m);
                    wm.retune_epoch = entry.retune_epoch();
                    wm
                })
                .map_err(|e| {
                    format!("model '{}' failed to build on this worker: {e:#}", entry.id)
                });
            self.models.insert(entry.id.clone(), built);
        }
    }

    #[cfg(test)]
    pub fn with_models(
        models: Vec<(&str, Box<dyn BatchModel>)>,
        generation: usize,
    ) -> ModelSet {
        ModelSet {
            models: models
                .into_iter()
                .map(|(id, m)| (id.to_string(), Ok(WorkerModel::new(m))))
                .collect(),
            generation,
        }
    }
}

/// How long an idle worker waits before re-checking the registry: bounds
/// how long an unregistered model's per-worker instances (weights +
/// detached plans) can outlive the unregistration on a pool with no
/// traffic to trigger a sync.
const IDLE_SYNC: Duration = Duration::from_millis(500);

/// Run until the queue is closed and drained.
pub(crate) fn worker_loop(set: &mut ModelSet, ctx: WorkerContext) {
    let mut pending: Vec<QueuedRequest> = Vec::new();
    loop {
        // Wait for the first live request; its claim picks the model this
        // flush serves. The wait is bounded so an idle worker still syncs
        // the registry (dropping instances of unregistered models). Then
        // drain greedily — same model only — until the batch is full or
        // the straggler window closes.
        let first = loop {
            match next_live(&ctx, Some(Instant::now() + IDLE_SYNC)) {
                Some(r) => break r,
                None if ctx.queue.is_closed() => {
                    // A timeout `None` raced the close: re-enter the pop.
                    // With the queue closed it returns the verdict
                    // atomically — an entry pushed before the close, or
                    // `None` only once closed *and* drained.
                    match next_live(&ctx, Some(Instant::now() + IDLE_SYNC)) {
                        Some(r) => break r,
                        None => return, // closed and drained: shut down
                    }
                }
                None => {
                    // Idle tick: registry sync, then the drift check —
                    // re-tuning only ever runs here, on a worker with no
                    // request in hand, so in-flight traffic is never
                    // delayed by a schedule search.
                    set.sync(&ctx.registry);
                    maybe_retune(set, &ctx);
                }
            }
        };
        set.sync(&ctx.registry);
        let model_id = first.claim.id().to_string();
        let batch = first.claim.spec().batch;
        pending.push(first);
        let flush_by = Instant::now() + ctx.max_wait;
        while pending.len() < batch {
            match next_live_model(&ctx, &model_id, flush_by) {
                ModelPop::Popped(r) => pending.push(r),
                ModelPop::Steal => {
                    // This model has no stragglers left to wait for while
                    // another model's backlog sits queued: cut the window,
                    // flush what we have, and take that backlog on the
                    // next (immediate) pop instead of idling out
                    // `max_wait`.
                    ctx.metrics.record_steal(ctx.id);
                    break;
                }
                ModelPop::Empty => break,
            }
        }
        flush(set, &ctx, &model_id, &mut pending);
    }
}

/// Validate, pad, execute and scatter one single-model batch. `pending` is
/// drained either way.
fn flush(set: &mut ModelSet, ctx: &WorkerContext, model_id: &str, pending: &mut Vec<QueuedRequest>) {
    let Some(first) = pending.first() else {
        return;
    };
    let spec = first.claim.spec();
    // Deadline re-check: a request popped live can expire while waiting
    // out the straggler window. Executing it anyway would return a stale
    // `Ok` past its deadline — reject it here instead, with the same typed
    // error and counter as a pop-time rejection. Width re-check: a
    // mismatched sample would panic `copy_from_slice` and take the whole
    // worker down.
    // Reject in place (the rejected entries are answered and dropped, the
    // rest keep their order): the one `pending` buffer is reused across
    // flushes, so the batcher hot path stays allocation-free.
    let now = Instant::now();
    pending.retain(|req| {
        if req.deadline.is_some_and(|dl| now >= dl) {
            // A shadow mirror that misses its window is dropped divergence
            // coverage, never a client-facing failure: the primary leg
            // answers (or already has), so the rejection counters the
            // rollout invariants assert zero on must stay untouched.
            // Dropping the request releases its leg of the `ShadowPair`,
            // whose `Drop` counts the incomplete pair as shadow-dropped.
            if let Some(RouteTag::Shadow { .. }) = &req.route {
            } else {
                ctx.metrics.record_rejected_deadline();
                ctx.metrics.record_model_rejected_deadline(model_id);
                let waited = req.enqueued.elapsed();
                let _ = req
                    .respond
                    .send(Err(ServeError::DeadlineExceeded { waited }));
            }
            false
        } else if req.x.len() != spec.in_dim {
            let _ = req.respond.send(Err(ServeError::WrongInputWidth {
                got: req.x.len(),
                want: spec.in_dim,
            }));
            false
        } else {
            true
        }
    });
    if pending.is_empty() {
        return;
    }
    let wm = match set.models.get_mut(model_id) {
        Some(Ok(wm)) => wm,
        Some(Err(msg)) => {
            let msg = msg.clone();
            fail_batch(ctx, model_id, pending, msg);
            return;
        }
        None => {
            fail_batch(
                ctx,
                model_id,
                pending,
                format!("model '{model_id}' is not resident on worker {}", ctx.id),
            );
            return;
        }
    };
    let (batch, in_dim, classes) = (spec.batch, spec.in_dim, spec.classes);
    // A worker instance must agree with the registered spec (factories are
    // deterministic); if one ever doesn't, answer typed errors instead of
    // unwinding on an out-of-bounds copy.
    if wm.x.len() != batch * in_dim {
        fail_batch(
            ctx,
            model_id,
            pending,
            format!("model '{model_id}' instance disagrees with its registered geometry"),
        );
        return;
    }
    wm.x.fill(0.0);
    // analyze: allow(panic-freedom, reason="x is sized batch*in_dim and pending.len() <= batch by the flush trigger")
    for (s, req) in pending.iter().enumerate() {
        wm.x[s * in_dim..(s + 1) * in_dim].copy_from_slice(&req.x);
    }
    match wm.model.forward(&wm.x) {
        Ok(logits) if logits.len() >= batch * classes => {
            ctx.metrics.record_flush(ctx.id, pending.len(), batch);
            ctx.metrics.record_model_flush(model_id, pending.len(), batch);
            for (s, req) in pending.drain(..).enumerate() {
                // analyze: allow(panic-freedom, reason="this match arm guarantees logits.len() >= batch*classes and s < batch")
                let row = &logits[s * classes..(s + 1) * classes];
                match &req.route {
                    // The mirror's only output is its divergence deposit:
                    // it never answers a client and never files client
                    // latency (it ran at Low priority on spare capacity —
                    // its wait time is not an SLO sample).
                    Some(RouteTag::Shadow { alias, pair }) => {
                        if let Some(d) = pair.record(true, row) {
                            ctx.metrics.record_shadow_divergence(alias, d);
                        }
                        continue;
                    }
                    Some(RouteTag::Alias {
                        alias,
                        canary,
                        shadow,
                    }) => {
                        let lat = req.enqueued.elapsed();
                        ctx.metrics.record_latency(ctx.id, lat);
                        ctx.metrics.record_alias_latency(alias, *canary, lat);
                        if let Some(pair) = shadow {
                            if let Some(d) = pair.record(false, row) {
                                ctx.metrics.record_shadow_divergence(alias, d);
                            }
                        }
                    }
                    None => ctx.metrics.record_latency(ctx.id, req.enqueued.elapsed()),
                }
                let _ = req.respond.send(Ok(row.to_vec()));
            }
            // Publish the model's tuned-schedule gauge (winning params,
            // roofline fraction, achieved-throughput EWMA) so `/stats`
            // readers see drift building up between idle-tick checks.
            ctx.metrics.set_model_tuned(model_id, wm.model.tuned_status());
        }
        Ok(logits) => {
            let msg = format!(
                "model '{model_id}' returned {} logits for a {batch}×{classes} batch",
                logits.len()
            );
            fail_batch(ctx, model_id, pending, msg);
        }
        Err(e) => {
            fail_batch(ctx, model_id, pending, format!("batch execution failed: {e}"));
        }
    }
}

/// Idle-tick drift check: re-tune every resident model whose achieved
/// throughput fell below `retune_threshold` of its tuned expectation.
/// Runs only on a worker with nothing to pop, so serving traffic never
/// waits on a schedule search; the model keeps answering its requests
/// from the old plans right up to the in-place swap. A failed re-tune is
/// skipped silently and retried on a later tick.
///
/// Pool coordination: the registry entry's re-tune guard admits exactly
/// one worker per drift event. The search invalidates the shared
/// TuneCache entry and evicts the plan namespace — two workers tripping
/// it in the same idle tick would double both and double-count
/// [`ModelStats::retunes`](crate::coordinator::metrics::ModelStats).
/// Losers skip this tick; a worker whose local epoch lags a peer's
/// *completed* re-tune refreshes its detached plans from the shared
/// cache instead ([`BatchModel::refresh`] — no search, no invalidation,
/// not counted). A model with no registry entry (drained away, or a
/// registry-less test fixture) falls back to the old ungated behavior.
fn maybe_retune(set: &mut ModelSet, ctx: &WorkerContext) {
    let Some(threshold) = ctx.retune_threshold else {
        return;
    };
    for (id, wm) in set.models.iter_mut() {
        let Ok(wm) = wm else { continue };
        let entry = ctx.registry.entry(id);
        if let Some(entry) = &entry {
            let epoch = entry.retune_epoch();
            if wm.retune_epoch != epoch {
                // A pool peer re-tuned this model: adopt its fresh plans.
                if wm.model.refresh().is_ok() {
                    wm.retune_epoch = epoch;
                    ctx.metrics.set_model_tuned(id, wm.model.tuned_status());
                }
                continue;
            }
        }
        let Some(drift) = wm.model.drift() else {
            continue; // untuned backend, or not enough flush samples yet
        };
        if drift >= threshold {
            continue;
        }
        if let Some(entry) = &entry {
            if !entry.try_begin_retune() {
                continue; // a peer is mid-search for this same drift event
            }
            if entry.retune_epoch() != wm.retune_epoch {
                // The peer finished between our epoch check and the guard
                // claim: this drift event is already handled — refresh on
                // the next tick instead of searching again.
                entry.end_retune();
                continue;
            }
        }
        if wm.model.retune().is_ok() {
            ctx.metrics.record_model_retune(id);
            ctx.metrics.set_model_tuned(id, wm.model.tuned_status());
            if let Some(entry) = &entry {
                entry.note_retuned();
                wm.retune_epoch = entry.retune_epoch();
            }
        }
        if let Some(entry) = &entry {
            entry.end_retune();
        }
    }
}

/// Answer every request in a failed batch with the typed backend error;
/// `pending` is drained.
fn fail_batch(
    ctx: &WorkerContext,
    model_id: &str,
    pending: &mut Vec<QueuedRequest>,
    msg: String,
) {
    ctx.metrics.record_error(ctx.id);
    ctx.metrics.record_model_error(model_id);
    for req in pending.drain(..) {
        let _ = req.respond.send(Err(ServeError::Backend(msg.clone())));
    }
}

/// Reject one expired request with the typed error and counters; it never
/// reaches [`BatchModel::forward`] and never occupies a batch slot. An
/// expired shadow mirror is dropped coverage, not a client failure — it
/// skips the rejection counters; dropping it releases its `ShadowPair`
/// leg, whose `Drop` files the incomplete pair as shadow-dropped.
fn reject_expired(ctx: &WorkerContext, req: QueuedRequest) {
    if let Some(RouteTag::Shadow { .. }) = &req.route {
        return;
    }
    ctx.metrics.record_rejected_deadline();
    ctx.metrics.record_model_rejected_deadline(req.claim.id());
    let _ = req.respond.send(Err(ServeError::DeadlineExceeded {
        waited: req.enqueued.elapsed(),
    }));
}

/// Pop the next request (any model) whose deadline is still live. With
/// `until = None` this blocks until the queue closes; otherwise it gives
/// up at `until`.
fn next_live(ctx: &WorkerContext, until: Option<Instant>) -> Option<QueuedRequest> {
    loop {
        let req = match until {
            None => ctx.queue.pop_blocking()?,
            Some(t) => ctx.queue.pop_until(t)?,
        };
        match req.deadline {
            Some(dl) if Instant::now() >= dl => reject_expired(ctx, req),
            _ => return Some(req),
        }
    }
}

/// Straggler pop: the next live request *for one model*, a
/// [`ModelPop::Steal`] hint when that model is drained but another
/// model's backlog waits, or [`ModelPop::Empty`] at `until`. Expired
/// entries are rejected in place, exactly as in [`next_live`].
fn next_live_model(ctx: &WorkerContext, model: &str, until: Instant) -> ModelPop {
    loop {
        match ctx.queue.pop_model_or_steal(model, until) {
            ModelPop::Popped(req) => match req.deadline {
                Some(dl) if Instant::now() >= dl => reject_expired(ctx, req),
                _ => return ModelPop::Popped(req),
            },
            other => return other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serving::queue::Priority;
    use crate::util::lock_recover;
    use crate::coordinator::serving::registry::ModelClaim;
    use std::sync::mpsc;

    /// Identity model: logits = the (single-feature) input, call log kept
    /// so tests can assert what reached `forward`.
    struct IdentityModel {
        batch: usize,
        seen: Arc<std::sync::Mutex<Vec<f32>>>,
    }

    impl BatchModel for IdentityModel {
        fn batch(&self) -> usize {
            self.batch
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            lock_recover(&self.seen).extend_from_slice(x);
            Ok(x.to_vec())
        }
    }

    fn identity_set(batch: usize) -> (ModelSet, Arc<std::sync::Mutex<Vec<f32>>>) {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let model = IdentityModel {
            batch,
            seen: Arc::clone(&seen),
        };
        (ModelSet::with_models(vec![("m", Box::new(model))], 0), seen)
    }

    fn ctx(queue: &Arc<RequestQueue>, metrics: &Arc<ServingMetrics>) -> WorkerContext {
        WorkerContext {
            id: 0,
            queue: Arc::clone(queue),
            metrics: Arc::clone(metrics),
            // Generation 0 matches the test ModelSet: sync is a no-op and
            // the dummy factories are never invoked.
            registry: Arc::new(ModelRegistry::new("m", 16)),
            max_wait: Duration::from_millis(1),
            retune_threshold: None,
            live: Arc::new(AtomicUsize::new(1)),
        }
    }

    fn queue() -> Arc<RequestQueue> {
        Arc::new(RequestQueue::new(16, None))
    }

    fn push(
        q: &RequestQueue,
        id: f32,
        deadline: Option<Duration>,
    ) -> mpsc::Receiver<Result<Vec<f32>, ServeError>> {
        push_sample(q, vec![id], deadline, 4)
    }

    fn push_sample(
        q: &RequestQueue,
        x: Vec<f32>,
        deadline: Option<Duration>,
        batch: usize,
    ) -> mpsc::Receiver<Result<Vec<f32>, ServeError>> {
        push_for(q, "m", x, deadline, batch)
    }

    fn push_for(
        q: &RequestQueue,
        model: &str,
        x: Vec<f32>,
        deadline: Option<Duration>,
        batch: usize,
    ) -> mpsc::Receiver<Result<Vec<f32>, ServeError>> {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        q.push(
            QueuedRequest {
                x,
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                respond: tx,
                claim: ModelClaim::detached(model, batch, 1, 1),
                route: None,
            },
            Priority::Normal,
            None,
        )
        .unwrap();
        rx
    }

    #[test]
    fn expired_requests_never_reach_forward() {
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let rx_dead = push(&queue, 5.0, Some(Duration::ZERO));
        let rx_live = push(&queue, 7.0, None);
        queue.close(); // worker drains then exits
        let (mut set, seen) = identity_set(4);
        worker_loop(&mut set, ctx(&queue, &metrics));
        match rx_dead.recv().unwrap() {
            Err(ServeError::DeadlineExceeded { .. }) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(rx_live.recv().unwrap().unwrap(), vec![7.0]);
        assert!(
            !lock_recover(&seen).contains(&5.0),
            "expired sample must not reach forward: {:?}",
            lock_recover(&seen)
        );
        assert_eq!(metrics.rejected(), (0, 1));
        assert_eq!(metrics.totals(), (1, 1), "one served request, one batch");
        let ms = metrics.model_stats();
        assert_eq!(ms.len(), 1);
        assert_eq!((ms[0].requests, ms[0].rejected_deadline), (1, 1));
    }

    #[test]
    fn deadline_expiring_inside_straggler_window_is_rejected_at_flush() {
        // The regression this covers: `next_live` pops the request while
        // its deadline is still live, the batch then waits out `max_wait`
        // (longer than the deadline), and the old flush executed it anyway.
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let rx = push(&queue, 3.0, Some(Duration::from_millis(20)));
        let mut ctx = ctx(&queue, &metrics);
        ctx.max_wait = Duration::from_millis(120); // straggler window ≫ deadline
        let (mut set, seen) = identity_set(4);
        let handle = std::thread::spawn({
            let queue = Arc::clone(&queue);
            move || {
                worker_loop(&mut set, ctx);
                drop(queue);
                seen
            }
        });
        // The worker pops the live request immediately, then sits in the
        // straggler window while the deadline lapses.
        match rx.recv().unwrap() {
            Err(ServeError::DeadlineExceeded { waited }) => {
                assert!(waited >= Duration::from_millis(20), "waited {waited:?}");
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        queue.close();
        let seen = handle.join().unwrap();
        assert!(lock_recover(&seen).is_empty(), "expired request must not execute");
        assert_eq!(metrics.rejected(), (0, 1));
        assert_eq!(metrics.totals(), (0, 0), "no batch was executed");
    }

    #[test]
    fn wrong_width_sample_gets_typed_error_not_a_worker_panic() {
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        // Bypasses the submit-time width check, as a buggy future submit
        // path might: in_dim is 1, this sample is 3 wide.
        let rx_bad = push_sample(&queue, vec![1.0, 2.0, 3.0], None, 4);
        let rx_ok = push(&queue, 9.0, None);
        queue.close();
        let (mut set, seen) = identity_set(4);
        worker_loop(&mut set, ctx(&queue, &metrics));
        match rx_bad.recv().unwrap() {
            Err(ServeError::WrongInputWidth { got, want }) => {
                assert_eq!((got, want), (3, 1));
            }
            other => panic!("expected WrongInputWidth, got {other:?}"),
        }
        // The worker survived and served the well-formed request.
        assert_eq!(rx_ok.recv().unwrap().unwrap(), vec![9.0]);
        assert!(!lock_recover(&seen).contains(&2.0));
        assert_eq!(metrics.totals(), (1, 1));
    }

    #[test]
    fn partial_batch_records_real_occupancy() {
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let rx1 = push_sample(&queue, vec![1.0], None, 8);
        let rx2 = push_sample(&queue, vec![2.0], None, 8);
        queue.close();
        let (mut set, _seen) = identity_set(8);
        worker_loop(&mut set, ctx(&queue, &metrics));
        assert!(rx1.recv().unwrap().is_ok());
        assert!(rx2.recv().unwrap().is_ok());
        let ws = metrics.worker_stats();
        assert_eq!(ws[0].batches, 1);
        assert_eq!(ws[0].occupied_slots, 2, "two real samples");
        assert_eq!(ws[0].batch_slots, 8, "eight slots executed");
        assert!((metrics.occupancy() - 0.25).abs() < 1e-12);
        let stats = metrics.latency_stats().unwrap();
        assert!((stats.occupancy - 0.25).abs() < 1e-12);
        let ms = metrics.model_stats();
        assert_eq!(ms[0].model, "m");
        assert!((ms[0].occupancy() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn steal_cuts_straggler_window_when_another_model_backlogs() {
        // Model "a" (batch 4) gets one request while model "b" has queued
        // work. The old loop idled out the full `max_wait` window hoping
        // for more "a" stragglers; with the steal hint the worker flushes
        // "a" immediately and serves "b" — under the old behavior both
        // responses would arrive only after the 8 s window.
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let mut ctx = ctx(&queue, &metrics);
        ctx.max_wait = Duration::from_secs(8);
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut set = ModelSet::with_models(
            vec![
                (
                    "a",
                    Box::new(IdentityModel {
                        batch: 4,
                        seen: Arc::clone(&seen),
                    }) as Box<dyn BatchModel>,
                ),
                (
                    "b",
                    Box::new(IdentityModel {
                        batch: 1,
                        seen: Arc::clone(&seen),
                    }) as Box<dyn BatchModel>,
                ),
            ],
            0,
        );
        let rx_a = push_for(&queue, "a", vec![1.0], None, 4);
        let rx_b = push_for(&queue, "b", vec![2.0], None, 1);
        let t0 = Instant::now();
        let handle = std::thread::spawn(move || worker_loop(&mut set, ctx));
        assert_eq!(
            rx_a.recv_timeout(Duration::from_secs(4)).unwrap().unwrap(),
            vec![1.0]
        );
        assert_eq!(
            rx_b.recv_timeout(Duration::from_secs(4)).unwrap().unwrap(),
            vec![2.0]
        );
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "straggler window was not cut by the steal hint"
        );
        queue.close();
        handle.join().unwrap();
        assert_eq!(metrics.worker_stats()[0].steals, 1, "one steal recorded");
        assert_eq!(metrics.totals(), (2, 2), "two single-model flushes");
        queue.check_invariants();
    }

    /// Model with a scripted drift ratio; `retune` resets it to healthy
    /// and counts invocations.
    struct DriftingModel {
        drift: Option<f64>,
        retunes: Arc<AtomicUsize>,
    }

    impl BatchModel for DriftingModel {
        fn batch(&self) -> usize {
            1
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            Ok(x.to_vec())
        }
        fn drift(&self) -> Option<f64> {
            self.drift
        }
        fn retune(&mut self) -> anyhow::Result<()> {
            self.retunes.fetch_add(1, Ordering::SeqCst);
            self.drift = Some(1.0); // fresh plans: back at expectation
            Ok(())
        }
    }

    #[test]
    fn idle_drift_check_retunes_only_models_below_threshold() {
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let mut ctx = ctx(&queue, &metrics);
        ctx.retune_threshold = Some(0.7);
        let slow = Arc::new(AtomicUsize::new(0));
        let others = Arc::new(AtomicUsize::new(0));
        let model = |drift, counter: &Arc<AtomicUsize>| -> Box<dyn BatchModel> {
            Box::new(DriftingModel {
                drift,
                retunes: Arc::clone(counter),
            })
        };
        let mut set = ModelSet::with_models(
            vec![
                ("slow", model(Some(0.4), &slow)),   // drifted: 0.4 < 0.7
                ("ok", model(Some(0.9), &others)),   // healthy
                ("cold", model(None, &others)),      // not enough samples
            ],
            0,
        );
        maybe_retune(&mut set, &ctx);
        assert_eq!(slow.load(Ordering::SeqCst), 1, "drifted model re-tuned");
        assert_eq!(others.load(Ordering::SeqCst), 0, "healthy/cold untouched");
        assert_eq!(metrics.retunes(), 1);
        let ms = metrics.model_stats();
        let s = ms.iter().find(|m| m.model == "slow").unwrap();
        assert_eq!(s.retunes, 1);
        // After the swap the model reports healthy drift: the next idle
        // tick must not re-tune it again.
        maybe_retune(&mut set, &ctx);
        assert_eq!(slow.load(Ordering::SeqCst), 1, "recovered model left alone");
        // Disabled threshold: the check is entirely off.
        ctx.retune_threshold = None;
        maybe_retune(&mut set, &ctx);
        assert_eq!(metrics.retunes(), 1);
    }

    /// Drifted model whose `retune` blocks on a gate, so a test can hold
    /// worker A *inside* the search while worker B's idle tick runs.
    struct GatedDriftModel {
        drift: Option<f64>,
        retunes: Arc<AtomicUsize>,
        refreshes: Arc<AtomicUsize>,
        /// `(entered, release)`: `retune` signals `entered` then blocks on
        /// `release`. `None` never blocks.
        gate: Option<(mpsc::Sender<()>, mpsc::Receiver<()>)>,
    }

    impl BatchModel for GatedDriftModel {
        fn batch(&self) -> usize {
            1
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            Ok(x.to_vec())
        }
        fn drift(&self) -> Option<f64> {
            self.drift
        }
        fn retune(&mut self) -> anyhow::Result<()> {
            if let Some((entered, release)) = &self.gate {
                let _ = entered.send(());
                let _ = release.recv();
            }
            self.retunes.fetch_add(1, Ordering::SeqCst);
            self.drift = Some(1.0);
            Ok(())
        }
        fn refresh(&mut self) -> anyhow::Result<()> {
            self.refreshes.fetch_add(1, Ordering::SeqCst);
            self.drift = Some(1.0);
            Ok(())
        }
    }

    #[test]
    fn same_tick_drift_on_two_workers_retunes_once_and_peer_refreshes() {
        use crate::coordinator::serving::registry::{ModelInfo, ModelRegistry, ModelSpec};

        // The regression this covers: both idle workers see model "m"
        // drifted in the same tick; without the registry-level guard both
        // ran the search, double-invalidating the TuneCache entry,
        // double-evicting the plan namespace and double-counting
        // `ModelStats::retunes`.
        let registry = Arc::new(ModelRegistry::new("m", 16));
        registry
            .register(
                "m",
                Arc::new(|| anyhow::bail!("test models are injected, not built")),
                Some(ModelInfo {
                    spec: ModelSpec {
                        batch: 1,
                        in_dim: 1,
                        classes: 1,
                    },
                    structures: Vec::new(),
                    cache: None,
                }),
                crate::coordinator::serving::ModelQuota::Unlimited,
            )
            .unwrap();
        let queue = Arc::new(RequestQueue::new(4, None));
        let metrics = Arc::new(ServingMetrics::new(2));
        let live = Arc::new(AtomicUsize::new(2));
        let mk_ctx = |id: usize| WorkerContext {
            id,
            queue: Arc::clone(&queue),
            metrics: Arc::clone(&metrics),
            registry: Arc::clone(&registry),
            max_wait: Duration::from_millis(1),
            retune_threshold: Some(0.7),
            live: Arc::clone(&live),
        };

        let (entered_tx, entered_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel();
        let a_retunes = Arc::new(AtomicUsize::new(0));
        let a_refreshes = Arc::new(AtomicUsize::new(0));
        let b_retunes = Arc::new(AtomicUsize::new(0));
        let b_refreshes = Arc::new(AtomicUsize::new(0));
        let mut set_a = ModelSet::with_models(
            vec![(
                "m",
                Box::new(GatedDriftModel {
                    drift: Some(0.4),
                    retunes: Arc::clone(&a_retunes),
                    refreshes: Arc::clone(&a_refreshes),
                    gate: Some((entered_tx, release_rx)),
                }) as Box<dyn BatchModel>,
            )],
            registry.generation(),
        );
        let mut set_b = ModelSet::with_models(
            vec![(
                "m",
                Box::new(GatedDriftModel {
                    drift: Some(0.4),
                    retunes: Arc::clone(&b_retunes),
                    refreshes: Arc::clone(&b_refreshes),
                    gate: None,
                }) as Box<dyn BatchModel>,
            )],
            registry.generation(),
        );

        // Worker A trips the re-tune and blocks inside the search.
        let ctx_a = mk_ctx(0);
        let worker_a = std::thread::spawn(move || {
            maybe_retune(&mut set_a, &ctx_a);
            ctx_a.metrics.retunes()
        });
        entered_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("worker A must enter its re-tune");
        // Worker B's idle tick lands while A holds the guard: it must
        // neither search nor count.
        let ctx_b = mk_ctx(1);
        maybe_retune(&mut set_b, &ctx_b);
        assert_eq!(b_retunes.load(Ordering::SeqCst), 0, "guard loser must not search");
        assert_eq!(metrics.retunes(), 0, "nothing completed yet");
        // Release A; exactly one re-tune lands.
        release_tx.send(()).unwrap();
        assert_eq!(worker_a.join().unwrap(), 1);
        assert_eq!(a_retunes.load(Ordering::SeqCst), 1);
        assert_eq!(metrics.retunes(), 1, "one drift event, one counted re-tune");
        assert_eq!(metrics.model_stats()[0].retunes, 1);
        // B's next tick observes the bumped epoch and refreshes from the
        // shared cache — still no second search, still one counted event.
        maybe_retune(&mut set_b, &ctx_b);
        assert_eq!(b_refreshes.load(Ordering::SeqCst), 1, "peer adopts fresh plans");
        assert_eq!(b_retunes.load(Ordering::SeqCst), 0);
        assert_eq!(metrics.retunes(), 1);
        // And once refreshed, B is quiescent.
        maybe_retune(&mut set_b, &ctx_b);
        assert_eq!(b_refreshes.load(Ordering::SeqCst), 1);
        queue.close();
    }

    /// Primary leg answers the client and the mirror leg only deposits
    /// divergence — never a response, never a latency sample, and an
    /// expired mirror drops coverage instead of bumping rejections.
    #[test]
    fn shadow_mirror_records_divergence_and_never_answers() {
        use crate::coordinator::serving::queue::ShadowPair;

        /// Logits = 2 × input: diverges from IdentityModel by |x|.
        struct DoublingModel;
        impl BatchModel for DoublingModel {
            fn batch(&self) -> usize {
                1
            }
            fn in_dim(&self) -> usize {
                1
            }
            fn classes(&self) -> usize {
                1
            }
            fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
                Ok(x.iter().map(|v| v * 2.0).collect())
            }
        }

        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut set = ModelSet::with_models(
            vec![
                (
                    "v1",
                    Box::new(IdentityModel {
                        batch: 1,
                        seen: Arc::clone(&seen),
                    }) as Box<dyn BatchModel>,
                ),
                ("v2", Box::new(DoublingModel) as Box<dyn BatchModel>),
            ],
            0,
        );
        let pair = ShadowPair::new("prod", &metrics);
        let now = Instant::now();
        let (tx, rx_primary) = mpsc::channel();
        queue
            .push(
                QueuedRequest {
                    x: vec![3.0],
                    enqueued: now,
                    deadline: None,
                    respond: tx,
                    claim: ModelClaim::detached("v1", 1, 1, 1),
                    route: Some(RouteTag::Alias {
                        alias: "prod".to_string(),
                        canary: false,
                        shadow: Some(Arc::clone(&pair)),
                    }),
                },
                Priority::Normal,
                None,
            )
            .unwrap();
        let (tx_mirror, rx_mirror) = mpsc::channel();
        queue
            .push(
                QueuedRequest {
                    x: vec![3.0],
                    enqueued: now,
                    deadline: None,
                    respond: tx_mirror,
                    claim: ModelClaim::detached("v2", 1, 1, 1),
                    route: Some(RouteTag::Shadow {
                        alias: "prod".to_string(),
                        pair: Arc::clone(&pair),
                    }),
                },
                Priority::Low,
                None,
            )
            .unwrap();
        // A second mirror whose deadline already lapsed: dropped coverage,
        // not a rejection.
        let (tx_late, rx_late) = mpsc::channel();
        queue
            .push(
                QueuedRequest {
                    x: vec![4.0],
                    enqueued: now,
                    deadline: Some(now),
                    respond: tx_late,
                    claim: ModelClaim::detached("v2", 1, 1, 1),
                    route: Some(RouteTag::Shadow {
                        alias: "prod".to_string(),
                        pair: ShadowPair::new("prod", &metrics),
                    }),
                },
                Priority::Low,
                None,
            )
            .unwrap();
        queue.close();
        worker_loop(&mut set, ctx(&queue, &metrics));
        // The client got the primary (v1) answer, bit-identical.
        assert_eq!(rx_primary.recv().unwrap().unwrap(), vec![3.0]);
        // The mirror never answered and the expired mirror never executed.
        assert!(matches!(rx_mirror.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
        assert!(matches!(rx_late.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
        // Divergence |3 - 6| = 3 landed under the alias; the expired
        // mirror shows up only as dropped shadow coverage.
        let alias_stats = metrics.alias_stats();
        assert_eq!(alias_stats.len(), 1);
        let s = &alias_stats[0];
        assert_eq!(s.alias, "prod");
        assert_eq!((s.requests, s.canary), (1, 0), "mirrors are not alias requests");
        assert_eq!(s.shadow_samples, 1);
        assert!((s.shadow_max - 3.0).abs() < 1e-9, "max-abs divergence 3.0");
        assert_eq!(s.shadow_dropped, 1);
        // Zero client-facing rejections: the rollout invariant.
        assert_eq!(metrics.rejected(), (0, 0));
    }

    /// Model that fails every forward: clients get the typed backend error.
    struct FailingModel;

    impl BatchModel for FailingModel {
        fn batch(&self) -> usize {
            2
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("kernel exploded")
        }
    }

    #[test]
    fn backend_errors_reach_every_request_in_batch() {
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let rx1 = push_sample(&queue, vec![1.0], None, 2);
        let rx2 = push_sample(&queue, vec![2.0], None, 2);
        queue.close();
        let mut set = ModelSet::with_models(vec![("m", Box::new(FailingModel))], 0);
        worker_loop(&mut set, ctx(&queue, &metrics));
        for rx in [rx1, rx2] {
            match rx.recv().unwrap() {
                Err(ServeError::Backend(msg)) => assert!(msg.contains("kernel exploded")),
                other => panic!("expected Backend error, got {other:?}"),
            }
        }
        assert_eq!(metrics.worker_stats()[0].errors, 1);
        assert_eq!(metrics.totals(), (0, 0), "failed batches are not throughput");
        assert_eq!(metrics.model_stats()[0].errors, 1);
    }

    #[test]
    fn failing_mirror_leg_settles_pair_and_counts_dropped() {
        // Regression: a ShadowPair whose mirror leg died with a backend
        // error never got its second deposit and was retained forever.
        // The pair must settle complete-or-expire when both legs' requests
        // are gone — counted once as shadow_dropped, pending gauge back to
        // zero.
        let queue = queue();
        let metrics = Arc::new(ServingMetrics::new(1));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut set = ModelSet::with_models(
            vec![
                (
                    "v1",
                    Box::new(IdentityModel {
                        batch: 1,
                        seen: Arc::clone(&seen),
                    }) as Box<dyn BatchModel>,
                ),
                ("v2", Box::new(FailingModel) as Box<dyn BatchModel>),
            ],
            0,
        );
        let now = Instant::now();
        let pair = ShadowPair::new("prod", &metrics);
        assert_eq!(metrics.shadow_pending(), 1, "begun pair is pending");
        let (tx, rx_primary) = mpsc::channel();
        queue
            .push(
                QueuedRequest {
                    x: vec![5.0],
                    enqueued: now,
                    deadline: None,
                    respond: tx,
                    claim: ModelClaim::detached("v1", 1, 1, 1),
                    route: Some(RouteTag::Alias {
                        alias: "prod".to_string(),
                        canary: false,
                        shadow: Some(Arc::clone(&pair)),
                    }),
                },
                Priority::Normal,
                None,
            )
            .unwrap();
        let (tx_mirror, rx_mirror) = mpsc::channel();
        queue
            .push(
                QueuedRequest {
                    x: vec![5.0],
                    enqueued: now,
                    deadline: None,
                    respond: tx_mirror,
                    claim: ModelClaim::detached("v2", 1, 1, 1),
                    route: Some(RouteTag::Shadow {
                        alias: "prod".to_string(),
                        pair: Arc::clone(&pair),
                    }),
                },
                Priority::Low,
                None,
            )
            .unwrap();
        queue.close();
        drop(pair); // only the queued legs keep the pair alive now
        worker_loop(&mut set, ctx(&queue, &metrics));
        // The client still got its primary answer; the mirror died in the
        // candidate's forward and never answers anyone.
        assert_eq!(rx_primary.recv().unwrap().unwrap(), vec![5.0]);
        assert!(matches!(rx_mirror.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
        // Both legs are gone: the pair settled — no leak — and the
        // incomplete pair was filed as dropped coverage exactly once.
        assert_eq!(metrics.shadow_pending(), 0, "no retained pair after both legs died");
        let alias_stats = metrics.alias_stats();
        assert_eq!(alias_stats.len(), 1);
        assert_eq!(alias_stats[0].shadow_dropped, 1);
        assert_eq!(alias_stats[0].shadow_samples, 0, "no divergence from a dead mirror");
    }
}
