//! The per-worker serving loop: pop → batch → pad → execute → scatter.
//!
//! Each worker thread owns one [`BatchModel`] instance and pulls from the
//! shared [`RequestQueue`]. It *dynamically batches*: block for the first
//! live request, then drain greedily — waiting at most `max_wait` for
//! stragglers — up to the model's batch size, pad the remainder with zero
//! rows, execute once, and scatter per-sample logits back through the
//! per-request channels.
//!
//! Deadline enforcement happens here, at pop time: an expired request is
//! answered with [`ServeError::DeadlineExceeded`] and *never occupies a
//! batch slot* — under overload the worker burns microseconds rejecting
//! stale work instead of milliseconds computing answers nobody is waiting
//! for.
//!
//! Metrics record *real* occupancy per flush (`pending.len()` of `batch`
//! slots), so padded partial batches are visible in the stats instead of
//! silently inflating throughput.

use super::backend::BatchModel;
use super::queue::{QueuedRequest, RequestQueue};
use super::ServeError;
use crate::coordinator::metrics::ServingMetrics;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Everything a worker thread needs besides its model. Doubles as the
/// worker's liveness guard: it is dropped when the worker exits — normal
/// shutdown, factory failure, *or panic unwind* — and the last drop closes
/// the queue and fails every still-queued request with
/// [`ServeError::Stopped`], so a pool whose workers have all died rejects
/// clients fast instead of letting them block on receivers forever.
pub(crate) struct WorkerContext {
    pub id: usize,
    pub queue: Arc<RequestQueue>,
    pub metrics: Arc<ServingMetrics>,
    /// Max time to wait for stragglers after the first request of a batch.
    pub max_wait: Duration,
    /// Count of workers still alive (shared across the pool).
    pub live: Arc<AtomicUsize>,
}

impl Drop for WorkerContext {
    fn drop(&mut self) {
        if self.live.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.queue.close_and_fail_pending();
        }
    }
}

/// Run until the queue is closed and drained.
pub(crate) fn worker_loop(model: &mut dyn BatchModel, ctx: WorkerContext) {
    let (batch, in_dim, classes) = (model.batch(), model.in_dim(), model.classes());
    // One padded batch buffer reused across flushes (the model executes
    // from cached plans; the batcher should not allocate per flush either).
    let mut x = vec![0.0f32; batch * in_dim];
    let mut pending: Vec<QueuedRequest> = Vec::with_capacity(batch);
    loop {
        // Block for the first live request; then drain greedily until the
        // batch is full or the straggler window closes.
        let Some(first) = next_live(&ctx, None) else {
            return; // queue closed and drained: shut down
        };
        pending.push(first);
        let flush_by = Instant::now() + ctx.max_wait;
        while pending.len() < batch {
            match next_live(&ctx, Some(flush_by)) {
                Some(r) => pending.push(r),
                None => break,
            }
        }
        flush(model, &ctx, &mut pending, &mut x, (batch, in_dim, classes));
    }
}

/// Pad, execute and scatter one batch. `pending` is drained either way.
fn flush(
    model: &mut dyn BatchModel,
    ctx: &WorkerContext,
    pending: &mut Vec<QueuedRequest>,
    x: &mut [f32],
    (batch, in_dim, classes): (usize, usize, usize),
) {
    x.fill(0.0);
    for (s, req) in pending.iter().enumerate() {
        x[s * in_dim..(s + 1) * in_dim].copy_from_slice(&req.x);
    }
    match model.forward(x) {
        Ok(logits) => {
            ctx.metrics.record_flush(ctx.id, pending.len(), batch);
            for (s, req) in pending.drain(..).enumerate() {
                let row = logits[s * classes..(s + 1) * classes].to_vec();
                ctx.metrics.record_latency(ctx.id, req.enqueued.elapsed());
                let _ = req.respond.send(Ok(row));
            }
        }
        Err(e) => {
            ctx.metrics.record_error(ctx.id);
            let msg = format!("batch execution failed: {e}");
            for req in pending.drain(..) {
                let _ = req.respond.send(Err(ServeError::Backend(msg.clone())));
            }
        }
    }
}

/// Pop the next request whose deadline is still live. Expired requests are
/// answered with the typed error immediately — they never reach
/// [`BatchModel::forward`] and never occupy a batch slot. With
/// `until = None` this blocks until the queue closes; otherwise it gives up
/// at `until` (straggler collection).
fn next_live(ctx: &WorkerContext, until: Option<Instant>) -> Option<QueuedRequest> {
    loop {
        let req = match until {
            None => ctx.queue.pop_blocking()?,
            Some(t) => ctx.queue.pop_until(t)?,
        };
        match req.deadline {
            Some(dl) if Instant::now() >= dl => {
                ctx.metrics.record_rejected_deadline();
                let _ = req.respond.send(Err(ServeError::DeadlineExceeded {
                    waited: req.enqueued.elapsed(),
                }));
            }
            _ => return Some(req),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serving::queue::Priority;
    use std::sync::mpsc;

    /// Identity model: logits = the (single-feature) input, call log kept
    /// so tests can assert what reached `forward`.
    struct IdentityModel {
        batch: usize,
        seen: Vec<f32>,
    }

    impl BatchModel for IdentityModel {
        fn batch(&self) -> usize {
            self.batch
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            self.seen.extend_from_slice(x);
            Ok(x.to_vec())
        }
    }

    fn ctx(queue: &Arc<RequestQueue>, metrics: &Arc<ServingMetrics>) -> WorkerContext {
        WorkerContext {
            id: 0,
            queue: Arc::clone(queue),
            metrics: Arc::clone(metrics),
            max_wait: Duration::from_millis(1),
            live: Arc::new(AtomicUsize::new(1)),
        }
    }

    fn push(
        q: &RequestQueue,
        id: f32,
        deadline: Option<Duration>,
    ) -> mpsc::Receiver<Result<Vec<f32>, ServeError>> {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        q.push(
            QueuedRequest {
                x: vec![id],
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                respond: tx,
            },
            Priority::Normal,
        )
        .unwrap();
        rx
    }

    #[test]
    fn expired_requests_never_reach_forward() {
        let queue = Arc::new(RequestQueue::new(16));
        let metrics = Arc::new(ServingMetrics::new(1));
        let rx_dead = push(&queue, 5.0, Some(Duration::ZERO));
        let rx_live = push(&queue, 7.0, None);
        queue.close(); // worker drains then exits
        let mut model = IdentityModel {
            batch: 4,
            seen: Vec::new(),
        };
        worker_loop(&mut model, ctx(&queue, &metrics));
        match rx_dead.recv().unwrap() {
            Err(ServeError::DeadlineExceeded { .. }) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(rx_live.recv().unwrap().unwrap(), vec![7.0]);
        assert!(
            !model.seen.contains(&5.0),
            "expired sample must not reach forward: {:?}",
            model.seen
        );
        assert_eq!(metrics.rejected(), (0, 1));
        assert_eq!(metrics.totals(), (1, 1), "one served request, one batch");
    }

    #[test]
    fn partial_batch_records_real_occupancy() {
        let queue = Arc::new(RequestQueue::new(16));
        let metrics = Arc::new(ServingMetrics::new(1));
        let rx1 = push(&queue, 1.0, None);
        let rx2 = push(&queue, 2.0, None);
        queue.close();
        let mut model = IdentityModel {
            batch: 8,
            seen: Vec::new(),
        };
        worker_loop(&mut model, ctx(&queue, &metrics));
        assert!(rx1.recv().unwrap().is_ok());
        assert!(rx2.recv().unwrap().is_ok());
        let ws = metrics.worker_stats();
        assert_eq!(ws[0].batches, 1);
        assert_eq!(ws[0].occupied_slots, 2, "two real samples");
        assert_eq!(ws[0].batch_slots, 8, "eight slots executed");
        assert!((metrics.occupancy() - 0.25).abs() < 1e-12);
        let stats = metrics.latency_stats().unwrap();
        assert!((stats.occupancy - 0.25).abs() < 1e-12);
    }

    /// Model that fails every forward: clients get the typed backend error.
    struct FailingModel;

    impl BatchModel for FailingModel {
        fn batch(&self) -> usize {
            2
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("kernel exploded")
        }
    }

    #[test]
    fn backend_errors_reach_every_request_in_batch() {
        let queue = Arc::new(RequestQueue::new(16));
        let metrics = Arc::new(ServingMetrics::new(1));
        let rx1 = push(&queue, 1.0, None);
        let rx2 = push(&queue, 2.0, None);
        queue.close();
        worker_loop(&mut FailingModel, ctx(&queue, &metrics));
        for rx in [rx1, rx2] {
            match rx.recv().unwrap() {
                Err(ServeError::Backend(msg)) => assert!(msg.contains("kernel exploded")),
                other => panic!("expected Backend error, got {other:?}"),
            }
        }
        assert_eq!(metrics.worker_stats()[0].errors, 1);
        assert_eq!(metrics.totals(), (0, 0), "failed batches are not throughput");
    }
}
