//! Multi-worker, **multi-model** batched inference serving: the L3
//! request path.
//!
//! ```text
//!  clients ──submit(model?, priority, deadline)──▶ RequestQueue (bounded)
//!                          │ pop (priority + age promotion, per-model stragglers)
//!          ┌───────────────┼───────────────┐
//!      worker 0         worker 1   …   worker N-1
//!   {model A, model B}  {model A, model B}        (one instance of every
//!          │                │                      registered model each)
//!          └───────┬────────┴───────┬──────┘
//!            ModelRegistry (id → factory/spec/namespaces)
//!            Arc<PlanCache> (structure derived once, executed everywhere)
//! ```
//!
//! [`InferenceServer::start_model`] spawns N worker threads from one model
//! *factory*; [`InferenceServer::register_model`] adds further models to
//! the same pool at runtime. Each worker owns its own instance of every
//! registered model (weights, scratch and detached plan copies are
//! per-worker, so flushes run truly in parallel with no shared lock on the
//! hot path), while all plan-cached models built from one shared
//! [`PlanCache`](crate::kernels::plan::PlanCache) resolve the *same*
//! cached derivations — cache builds scale with distinct *structures*, not
//! models × workers. [`InferenceServer::unregister_model`] drains a
//! model's in-flight requests, drops its worker instances, and evicts
//! exactly the plan namespaces no surviving model claims ([`registry`]).
//!
//! Requests flow through a **bounded priority queue** ([`queue`]):
//! * a full queue rejects the submit with [`ServeError::QueueFull`]
//!   (backpressure at the caller, not unbounded memory growth);
//! * [`Priority::High`] pops before [`Priority::Normal`] before
//!   [`Priority::Low`], FIFO within a class — but an entry older than
//!   [`ServerConfig::max_starvation`] is promoted one class per period,
//!   so Low traffic is delayed, never starved;
//! * an expired deadline gets [`ServeError::DeadlineExceeded`] at pop time
//!   *and again at flush time* (the straggler window can outlive a short
//!   deadline) and is never executed ([`worker`]);
//! * an unregistered model id is rejected synchronously with
//!   [`ServeError::UnknownModel`].
//!
//! Each worker *dynamically batches per model*: the first popped request
//! picks the model, stragglers are drained for that model only (a flush
//! never mixes models), the final partial batch is padded, executed once,
//! and per-sample logits scatter back through per-request channels.
//! Metrics ([`ServingMetrics`]) are per-worker atomics plus per-model
//! tallies and real batch-occupancy accounting, and keep working even if
//! a worker dies mid-record. [`InferenceServer::shutdown`] closes the
//! queue, lets workers drain every queued request, and joins them.

pub mod backend;
pub mod queue;
pub mod registry;
mod worker;

pub use backend::{BatchModel, NativeSparseModel};
pub use queue::{ModelPop, Priority, QueuedRequest, RequestQueue, RouteTag, ShadowPair, SubmitOptions};
pub use registry::{AliasInfo, ModelClaim, UnregisterReport, DEFAULT_MODEL};

use crate::coordinator::metrics::{AliasStats, LatencyStats, ModelStats, ServingMetrics, WorkerStats};
use crate::util::lock_recover;
use registry::{request_key, ModelFactory, ModelInfo, ModelRegistry, ModelSpec};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Typed serving errors — the contract clients program against.
/// Backpressure and deadline misses are first-class outcomes under
/// overload, not stringly-typed surprises.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded request queue is at capacity; retry later or shed load.
    QueueFull { cap: usize },
    /// The target model already has `quota` requests queued (its resolved
    /// [`ModelQuota`]); the submit was rejected at admission so this model
    /// cannot exhaust the queue capacity other models share. Distinct
    /// from [`ServeError::QueueFull`]: only this model must back off.
    ModelQuotaExceeded { model: String, quota: usize },
    /// The request's deadline expired before a worker could serve it.
    DeadlineExceeded { waited: Duration },
    /// The sample width does not match the target model's input dimension.
    WrongInputWidth { got: usize, want: usize },
    /// The submit named a model id that is not registered (or was
    /// unregistered).
    UnknownModel { model: String },
    /// The submit raced a registration: the model exists but its probe has
    /// not reported geometry yet. Transient — retry shortly.
    ModelNotReady { model: String },
    /// The server has been shut down (or every worker exited).
    Stopped,
    /// The backend failed executing the batch this request rode in.
    Backend(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { cap } => {
                write!(f, "request queue full (capacity {cap}): backpressure")
            }
            ServeError::ModelQuotaExceeded { model, quota } => {
                write!(
                    f,
                    "model '{model}' is at its queue quota ({quota} queued): backpressure"
                )
            }
            ServeError::DeadlineExceeded { waited } => {
                write!(f, "deadline exceeded after {:.3} ms in queue", waited.as_secs_f64() * 1e3)
            }
            ServeError::WrongInputWidth { got, want } => {
                write!(f, "sample has {got} features, model wants {want}")
            }
            ServeError::UnknownModel { model } => {
                write!(f, "model '{model}' is not registered with this server")
            }
            ServeError::ModelNotReady { model } => {
                write!(f, "model '{model}' is still initializing (probe pending); retry")
            }
            ServeError::Stopped => write!(f, "server stopped"),
            ServeError::Backend(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-model admission quota: the most requests one model may have
/// *queued* (accepted but not yet popped by a worker) at a time. With the
/// default [`ModelQuota::Unlimited`] a single hot model can fill the
/// entire bounded queue and starve every other model's submits into
/// [`ServeError::QueueFull`]; a quota converts that into per-model
/// backpressure ([`ServeError::ModelQuotaExceeded`]) while cold models
/// keep submitting. The registry stores the *policy* and re-resolves the
/// absolute limit whenever registry membership changes
/// ([`ModelQuota::resolve`]), so fair shares track the live model count
/// instead of going stale after the first registration.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ModelQuota {
    /// No per-model bound; only the shared queue capacity applies.
    #[default]
    Unlimited,
    /// At most this many queued requests (clamped to ≥ 1 — a model with
    /// zero admission could never be served at all).
    Absolute(usize),
    /// At most this fraction of the queue capacity, split evenly across
    /// the models currently live in the registry (clamped to `[0, 1]`, at
    /// least 1 slot). With two live models, `FairShare(0.5)` admits a
    /// quarter of the queue each; a third registration shrinks every
    /// fair-share cap, and a retirement widens them again.
    FairShare(f64),
}

impl ModelQuota {
    /// Resolve to an absolute queued-request limit against `queue_cap`
    /// and the number of currently live models; `None` means unlimited.
    /// `Unlimited` and `Absolute` ignore membership; `FairShare` divides
    /// its fraction of the queue across `live_models`.
    pub fn resolve(&self, queue_cap: usize, live_models: usize) -> Option<usize> {
        match *self {
            ModelQuota::Unlimited => None,
            ModelQuota::Absolute(n) => Some(n.max(1)),
            ModelQuota::FairShare(f) => {
                let share = (f.clamp(0.0, 1.0) * queue_cap as f64).floor() as usize;
                Some((share / live_models.max(1)).max(1))
            }
        }
    }

    /// Resolve as if this model were the only one live — the cap a
    /// fair-share model starts from before anyone else registers.
    pub fn limit(&self, queue_cap: usize) -> Option<usize> {
        self.resolve(queue_cap, 1)
    }
}

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max time a worker waits to fill a batch before flushing.
    pub max_wait: Duration,
    /// Optional trained checkpoint to serve (JSON, `Trainer::save_checkpoint`
    /// schema); defaults to the exported init parameters. XLA backend only.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Worker threads, each owning one `BatchModel` instance (min 1).
    pub workers: usize,
    /// Bounded queue capacity; submits beyond it get
    /// [`ServeError::QueueFull`] (min 1).
    pub queue_cap: usize,
    /// Deadline applied to requests that don't carry their own
    /// ([`SubmitOptions::deadline`] wins); `None` waits indefinitely.
    pub default_deadline: Option<Duration>,
    /// Age-promotion period for queued requests: an entry waiting longer
    /// than this is promoted one priority class per elapsed period
    /// (Low → Normal → High), bounding starvation under sustained
    /// higher-class load. `None` restores strict priority (Low can starve
    /// forever).
    pub max_starvation: Option<Duration>,
    /// Default per-model admission quota, applied to the initial model and
    /// to every [`InferenceServer::register_model`] registration;
    /// [`InferenceServer::register_model_with_quota`] overrides it per
    /// model.
    pub model_quota: ModelQuota,
    /// Persistent tuning-cache file ([`TuneCache`]) attached to every
    /// plan-cached model the pool builds: searched winners are recorded
    /// there and later processes warm-start from it. Attachment is
    /// first-wins per [`PlanCache`](crate::kernels::plan::PlanCache) — a
    /// caller that already attached one (e.g. `rbgp serve --tune-cache`
    /// attaches before the factory warms, so even the *first* build
    /// warm-starts) keeps its handle.
    pub tune_cache: Option<std::path::PathBuf>,
    /// Drift re-tune threshold: when a model's achieved/tuned throughput
    /// ratio drops below this, an idle worker re-runs its schedule search
    /// and swaps plans in place (serving never blocks on it). `None`
    /// disables drift re-tuning.
    pub retune_threshold: Option<f64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(5),
            checkpoint: None,
            workers: 1,
            queue_cap: 1024,
            default_deadline: None,
            max_starvation: Some(Duration::from_secs(1)),
            model_quota: ModelQuota::Unlimited,
            tune_cache: None,
            retune_threshold: Some(0.7),
        }
    }
}

struct ServerInner {
    queue: Arc<RequestQueue>,
    metrics: Arc<ServingMetrics>,
    registry: Arc<ModelRegistry>,
    workers: usize,
    default_deadline: Option<Duration>,
    /// Default admission quota for models registered after startup.
    model_quota: ModelQuota,
    /// Persistent tuning cache opened from [`ServerConfig::tune_cache`],
    /// attached to each newly registered model's plan cache.
    tune_cache: Option<Arc<crate::kernels::TuneCache>>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

/// Attach the server's persistent tuning cache to a model's plan cache
/// (first-wins, no-op for backends without one).
fn attach_tune_cache(tune: &Option<Arc<crate::kernels::TuneCache>>, model: &dyn BatchModel) {
    if let (Some(tc), Some(pc)) = (tune, model.plan_cache()) {
        pc.attach_tune_cache(Arc::clone(tc));
    }
}

impl ServerInner {
    /// Close the queue (new submits fail with `Stopped`), let workers drain
    /// every queued request, and join them. Idempotent.
    fn shutdown(&self) {
        self.queue.close();
        let mut handles = lock_recover(&self.handles);
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServerInner {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Handle to a running server; cloneable across client threads. Dropping
/// the last clone shuts the server down (drain + join).
#[derive(Clone)]
pub struct InferenceServer {
    inner: Arc<ServerInner>,
    pub in_dim: usize,
    pub classes: usize,
    pub batch: usize,
}

impl InferenceServer {
    /// Start `config.workers` worker threads around any [`BatchModel`],
    /// registered under [`DEFAULT_MODEL`]. The factory runs once *on each*
    /// worker thread (some backends — PJRT — own handles that are not
    /// `Send`); every instance's result (or error) is reported back before
    /// this constructor returns, and all instances must agree on batch
    /// geometry.
    ///
    /// To share one [`PlanCache`](crate::kernels::plan::PlanCache) across
    /// the pool, capture the `Arc` in the factory and clone it into each
    /// model — see `NativeTrainer::serving_factory`.
    pub fn start_model<F>(factory: F, config: ServerConfig) -> anyhow::Result<InferenceServer>
    where
        F: Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static,
    {
        InferenceServer::start_model_as(DEFAULT_MODEL, factory, config)
    }

    /// [`InferenceServer::start_model`] with an explicit id for the
    /// initial (default) model — requests without a
    /// [`SubmitOptions::model`] route to it. Further models join the same
    /// pool through [`InferenceServer::register_model`].
    pub fn start_model_as<F>(
        default_id: &str,
        factory: F,
        config: ServerConfig,
    ) -> anyhow::Result<InferenceServer>
    where
        F: Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static,
    {
        let workers = config.workers.max(1);
        let queue = Arc::new(RequestQueue::new(
            config.queue_cap.max(1),
            config.max_starvation,
        ));
        let metrics = Arc::new(ServingMetrics::new(workers));
        let registry = Arc::new(ModelRegistry::new(default_id, queue.capacity()));
        // Open the persistent tuning cache once (fail-soft by
        // construction) and attach it to every model the pool builds: a
        // factory that warms *after* the attach searches warm, and every
        // search records its winner to the file for later processes.
        let tune_cache = config
            .tune_cache
            .as_ref()
            .map(crate::kernels::TuneCache::open);
        let factory = {
            let tune = tune_cache.clone();
            move || {
                let model = factory()?;
                attach_tune_cache(&tune, model.as_ref());
                Ok(model)
            }
        };
        // The default model's info (geometry, plan namespaces) is reported
        // by the first worker instance below — before this constructor
        // returns, so no submit can observe the entry without it.
        let default_entry = registry.register(
            default_id,
            Arc::new(factory),
            None,
            config.model_quota,
        )?;
        // Liveness counter for the whole pool: each worker's context
        // decrements it on exit (including panic unwind); the last one out
        // closes the queue and fails pending requests with `Stopped`.
        let live = Arc::new(std::sync::atomic::AtomicUsize::new(workers));
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<worker::ReadyReport>>();
        let mut handles = Vec::with_capacity(workers);
        for id in 0..workers {
            let ready_tx = ready_tx.clone();
            let ctx = worker::WorkerContext {
                id,
                queue: Arc::clone(&queue),
                metrics: Arc::clone(&metrics),
                registry: Arc::clone(&registry),
                max_wait: config.max_wait,
                retune_threshold: config.retune_threshold,
                live: Arc::clone(&live),
            };
            let spawned = thread::Builder::new()
                .name(format!("rbgp-serve-{id}"))
                .spawn(move || {
                    let mut set = worker::ModelSet::default();
                    match set.build_initial(&ctx.registry) {
                        Ok(report) => {
                            let _ = ready_tx.send(Ok(report));
                            drop(ready_tx);
                            worker::worker_loop(&mut set, ctx);
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                        }
                    }
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e.into());
                }
            }
        }
        drop(ready_tx);

        // Collect one readiness report per worker; any failure (or geometry
        // disagreement) aborts startup cleanly — close, join, error out.
        let mut dims: Option<(usize, usize, usize)> = None;
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(report)) => {
                    let d = (report.batch, report.in_dim, report.classes);
                    match dims {
                        None => {
                            dims = Some(d);
                            default_entry.set_info(ModelInfo {
                                spec: ModelSpec {
                                    batch: report.batch,
                                    in_dim: report.in_dim,
                                    classes: report.classes,
                                },
                                structures: report.structures,
                                cache: report.cache,
                            });
                        }
                        Some(prev) if prev != d => {
                            startup_err.get_or_insert_with(|| {
                                anyhow::anyhow!(
                                    "workers disagree on model geometry: {prev:?} vs {d:?}"
                                )
                            });
                        }
                        Some(_) => {}
                    }
                }
                Ok(Err(e)) => {
                    startup_err.get_or_insert(e);
                }
                Err(_) => {
                    startup_err.get_or_insert_with(|| {
                        anyhow::anyhow!("server worker died during startup")
                    });
                }
            }
        }
        if let Some(e) = startup_err {
            queue.close();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        let (batch, in_dim, classes) = dims.expect("workers >= 1 reported ready");
        Ok(InferenceServer {
            inner: Arc::new(ServerInner {
                queue,
                metrics,
                registry,
                workers,
                default_deadline: config.default_deadline,
                model_quota: config.model_quota,
                tune_cache,
                handles: Mutex::new(handles),
            }),
            in_dim,
            classes,
            batch,
        })
    }

    /// Register another model with the running pool under `id`, admitted
    /// under the server's default [`ServerConfig::model_quota`]. The
    /// factory is probed once on the calling thread — validating it,
    /// capturing geometry and plan namespaces, and (for factories that
    /// warm) pre-building the structure's plans in the shared cache so
    /// each worker's own build resolves as a cache hit. Workers
    /// materialize their instances lazily at the next request; a
    /// worker-side build failure degrades that worker's answers for this
    /// model to [`ServeError::Backend`] instead of killing the pool.
    pub fn register_model<F>(&self, id: &str, factory: F) -> anyhow::Result<()>
    where
        F: Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static,
    {
        self.register_model_with_quota(id, self.inner.model_quota, factory)
    }

    /// [`InferenceServer::register_model`] with an explicit per-model
    /// admission quota overriding the server default — e.g. a known-hot
    /// model capped to [`ModelQuota::FairShare`] of the queue so batch
    /// tenants cannot starve interactive ones out of queue capacity.
    pub fn register_model_with_quota<F>(
        &self,
        id: &str,
        quota: ModelQuota,
        factory: F,
    ) -> anyhow::Result<()>
    where
        F: Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static,
    {
        anyhow::ensure!(
            !self.inner.queue.is_closed(),
            "cannot register '{id}': server is stopped"
        );
        // Reject a taken id before probing: the probe warms plans into the
        // shared cache, and plans built for a registration that then fails
        // would belong to no entry — unevictable until process exit. (A
        // concurrent same-id race can still reach the probe; the atomic
        // check in `register` below stays authoritative.)
        anyhow::ensure!(
            !self.inner.registry.is_registered(id),
            "model '{id}' is already registered"
        );
        let factory: ModelFactory = {
            let tune = self.inner.tune_cache.clone();
            Arc::new(move || {
                let model = factory()?;
                attach_tune_cache(&tune, model.as_ref());
                Ok(model)
            })
        };
        let probe = factory()?;
        let info = ModelInfo {
            spec: ModelSpec {
                batch: probe.batch(),
                in_dim: probe.in_dim(),
                classes: probe.classes(),
            },
            structures: probe.structures(),
            cache: probe.plan_cache(),
        };
        drop(probe);
        self.inner.registry.register(id, factory, Some(info), quota)?;
        Ok(())
    }

    /// Retire a model: stop accepting submits for `id` (they get
    /// [`ServeError::UnknownModel`]), **drain** every in-flight request
    /// for it (each is answered), drop the per-worker instances, and evict
    /// exactly the plan-cache namespaces no surviving model still claims —
    /// closing the structure lifecycle the gradual trainer opened. The
    /// report carries exact eviction counters.
    pub fn unregister_model(&self, id: &str) -> anyhow::Result<UnregisterReport> {
        let entry = self.inner.registry.begin_retire(id)?;
        let drained_requests = entry.in_flight();
        entry.wait_drained();
        let mut report = self.inner.registry.finish_retire(&entry);
        report.drained_requests = drained_requests;
        Ok(report)
    }

    // ─── Rollout operations: aliases, canary routing, shadow mode ───────
    //
    // An alias (`prod` → concrete model id) is the client-facing name for
    // fleet rollouts: clients keep submitting to `prod` while operators
    // stage a new model behind it (canary a fraction of traffic, shadow
    // everything for divergence measurement) and finally flip the alias
    // atomically. See `registry` for locking semantics.

    /// Create or redirect an alias to a registered concrete model. Alias
    /// and model-id namespaces are disjoint (both directions); creating an
    /// alias over an existing model id, or vice versa, fails.
    pub fn set_alias(&self, alias: &str, target: &str) -> anyhow::Result<()> {
        self.inner.registry.set_alias(alias, target)
    }

    /// Atomically flip `alias` to `target` and clear any staged canary /
    /// shadow configuration — the staging referred to the *previous*
    /// primary. Requests resolved before the flip drain on the old model
    /// (their claims pin it); requests resolved after see only the new one.
    pub fn promote(&self, alias: &str, target: &str) -> anyhow::Result<()> {
        self.inner.registry.promote(alias, target)
    }

    /// Delete an alias. Concrete models stay registered and directly
    /// addressable.
    pub fn remove_alias(&self, alias: &str) -> anyhow::Result<()> {
        self.inner.registry.remove_alias(alias)
    }

    /// Route `percent`% (1..=100) of the alias's traffic to `target`,
    /// chosen per request by a deterministic payload hash. The target must
    /// match the primary's input/output geometry.
    pub fn set_canary(&self, alias: &str, target: &str, percent: u8) -> anyhow::Result<()> {
        self.inner.registry.set_canary(alias, target, percent)
    }

    /// Stop canary routing; all alias traffic returns to the primary.
    pub fn clear_canary(&self, alias: &str) -> anyhow::Result<()> {
        self.inner.registry.clear_canary(alias)
    }

    /// Mirror every alias request to `target` on spare capacity (Low
    /// priority, best effort) and record per-request max-abs logit
    /// divergence into [`InferenceServer::alias_stats`]. Clients are
    /// always answered by the primary leg. The target must match the
    /// primary's geometry.
    pub fn set_shadow(&self, alias: &str, target: &str) -> anyhow::Result<()> {
        self.inner.registry.set_shadow(alias, target)
    }

    /// Stop shadow mirroring.
    pub fn clear_shadow(&self, alias: &str) -> anyhow::Result<()> {
        self.inner.registry.clear_shadow(alias)
    }

    /// Current alias routes (target, canary, shadow), sorted by alias.
    pub fn aliases(&self) -> Vec<AliasInfo> {
        self.inner.registry.aliases()
    }

    /// The concrete model an alias currently resolves to.
    pub fn alias_target(&self, alias: &str) -> Option<String> {
        self.inner.registry.alias_target(alias)
    }

    /// Per-alias serving stats: request/canary counters, latency
    /// percentiles over the recent window, and the shadow-divergence
    /// histogram.
    pub fn alias_stats(&self) -> Vec<AliasStats> {
        self.inner.metrics.alias_stats()
    }

    /// Zero-downtime rollout as one operation: atomically flip `alias` to
    /// `to`, then drain and retire the previous primary — awaiting its
    /// in-flight count reaching zero and evicting exactly the plan
    /// namespaces no surviving model claims. Requests accepted before the
    /// flip are all answered (by the old model); requests after resolve to
    /// the new one. Nothing is dropped.
    pub fn rollout(&self, alias: &str, to: &str) -> anyhow::Result<UnregisterReport> {
        let old = self
            .inner
            .registry
            .alias_target(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?;
        anyhow::ensure!(
            old != to,
            "alias '{alias}' already points at '{to}': nothing to roll out"
        );
        self.inner.registry.promote(alias, to)?;
        self.unregister_model(&old)
    }

    /// Ids of the currently registered models, sorted.
    pub fn models(&self) -> Vec<String> {
        self.inner.registry.models()
    }

    /// Per-model serving counters (includes retired models' history).
    pub fn model_stats(&self) -> Vec<ModelStats> {
        self.inner.metrics.model_stats()
    }

    /// Start serving a compiled AOT artifact on the PJRT client (feature
    /// `xla`). Each worker compiles the artifact itself (PJRT handles are
    /// not `Send`) and reports readiness (or the compile error) back before
    /// the constructor returns.
    #[cfg(feature = "xla")]
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        config: ServerConfig,
    ) -> anyhow::Result<InferenceServer> {
        let checkpoint = config.checkpoint.clone();
        InferenceServer::start_model(
            move || {
                let model = backend::xla_backend::XlaModel::load(&artifacts_dir, checkpoint.clone())?;
                Ok(Box::new(model) as Box<dyn BatchModel>)
            },
            config,
        )
    }

    /// Submit one sample with default options; returns a receiver that
    /// yields the logits (or a typed [`ServeError`]).
    pub fn submit(
        &self,
        x: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, ServeError>>, ServeError> {
        self.submit_with(x, SubmitOptions::default())
    }

    /// Submit one sample with explicit priority / deadline / target model
    /// **or alias**. Backpressure — shared ([`ServeError::QueueFull`]) or
    /// per-model ([`ServeError::ModelQuotaExceeded`]) — shutdown
    /// ([`ServeError::Stopped`]), an unknown model id
    /// ([`ServeError::UnknownModel`]), a registration race
    /// ([`ServeError::ModelNotReady`]) and a width mismatch against the
    /// *target model's* input dimension are reported synchronously;
    /// deadline expiry arrives on the receiver.
    ///
    /// An aliased submit resolves to its concrete model *here*, under the
    /// registry lock — the queued claim pins that concrete model, so a
    /// concurrent [`InferenceServer::promote`] never reroutes an accepted
    /// request. The canary leg is chosen by a deterministic hash of the
    /// payload and alias name (replaying a request always lands on the
    /// same leg), and a configured shadow target enqueues a best-effort
    /// Low-priority mirror whose only output is a divergence sample — the
    /// client answer always comes from the primary leg.
    pub fn submit_with(
        &self,
        x: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, ServeError>>, ServeError> {
        let requested = opts.model.as_deref();
        let key = request_key(&x, requested.unwrap_or_else(|| self.inner.registry.default_id()));
        let res = self.inner.registry.resolve_request(requested, key)?;
        let want = res.claim.spec().in_dim;
        if x.len() != want {
            return Err(ServeError::WrongInputWidth { got: x.len(), want });
        }
        let quota = res.claim.quota_limit();
        let now = Instant::now();
        let deadline = opts
            .deadline
            .or(self.inner.default_deadline)
            .map(|d| now + d);
        // Routing context + optional shadow mirror. The mirror rides the
        // same payload and deadline but a dummy response channel: it can
        // never answer a client.
        let (route, mirror) = match res.alias {
            Some((alias, canary)) => match res.shadow {
                Some(shadow_claim) => {
                    let pair = ShadowPair::new(&alias, &self.inner.metrics);
                    let mirror_quota = shadow_claim.quota_limit();
                    let mirror_req = QueuedRequest {
                        x: x.clone(),
                        enqueued: now,
                        deadline,
                        respond: mpsc::channel().0,
                        claim: shadow_claim,
                        route: Some(RouteTag::Shadow {
                            alias: alias.clone(),
                            pair: Arc::clone(&pair),
                        }),
                    };
                    (
                        Some(RouteTag::Alias {
                            alias,
                            canary,
                            shadow: Some(pair),
                        }),
                        Some((mirror_req, mirror_quota)),
                    )
                }
                None => (
                    Some(RouteTag::Alias {
                        alias,
                        canary,
                        shadow: None,
                    }),
                    None,
                ),
            },
            None => (None, None),
        };
        let (rtx, rrx) = mpsc::channel();
        let depth = self.inner.queue.push(
            QueuedRequest {
                x,
                enqueued: now,
                deadline,
                respond: rtx,
                claim: res.claim,
                route,
            },
            opts.priority,
            quota,
        );
        let depth = match depth {
            Ok(d) => d,
            Err(e) => {
                match &e {
                    ServeError::QueueFull { .. } => self.inner.metrics.record_rejected_full(),
                    ServeError::ModelQuotaExceeded { model, .. } => {
                        self.inner.metrics.record_rejected_quota();
                        self.inner.metrics.record_model_rejected_quota(model);
                    }
                    _ => {}
                }
                // A rejected primary mirrors nothing.
                return Err(e);
            }
        };
        self.inner.metrics.observe_queue_depth(depth);
        // The mirror is enqueued only after the primary was accepted, at
        // Low priority against the shadow model's own quota. A rejected
        // mirror is a dropped divergence sample, never a client-visible
        // rejection — dropping the rejected request here releases its leg
        // of the `ShadowPair`, whose `Drop` settles the incomplete pair as
        // `shadow_dropped`.
        if let Some((req, mirror_quota)) = mirror {
            let _ = self.inner.queue.push(req, Priority::Low, mirror_quota);
        }
        Ok(rrx)
    }

    /// Blocking convenience: submit and wait for logits.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>, ServeError> {
        self.infer_with(x, SubmitOptions::default())
    }

    /// Blocking convenience with explicit priority / deadline.
    pub fn infer_with(&self, x: Vec<f32>, opts: SubmitOptions) -> Result<Vec<f32>, ServeError> {
        self.submit_with(x, opts)?
            .recv()
            .map_err(|_| ServeError::Stopped)?
    }

    /// Latency percentiles + batch-occupancy gauge. Never panics, even if
    /// a worker died mid-record.
    pub fn latency_stats(&self) -> Option<LatencyStats> {
        self.inner.metrics.latency_stats()
    }

    /// `(answered requests, executed batches)` summed over all workers.
    pub fn counters(&self) -> (usize, usize) {
        self.inner.metrics.totals()
    }

    /// Per-worker counter snapshots.
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        self.inner.metrics.worker_stats()
    }

    /// `(queue-full rejects, deadline-expired rejects)`.
    pub fn rejected(&self) -> (usize, usize) {
        self.inner.metrics.rejected()
    }

    /// Submits rejected at admission because the target model's queue
    /// quota was saturated ([`ServeError::ModelQuotaExceeded`]), all
    /// models; `model_stats` has the per-model split.
    pub fn rejected_quota(&self) -> usize {
        self.inner.metrics.rejected_quota()
    }

    /// Straggler windows workers cut short to serve another model's
    /// backlog instead of idling (work steals), summed over workers;
    /// `worker_stats` has the per-worker split.
    pub fn steals(&self) -> usize {
        self.inner.metrics.steals()
    }

    /// Drift-triggered plan re-tunes performed by idle workers, summed
    /// over models; `model_stats` carries the per-model split and each
    /// model's per-layer [`TunedStatus`](crate::coordinator::metrics::TunedStatus)
    /// gauge (winning schedule, roofline fraction, achieved-throughput
    /// EWMA).
    pub fn retunes(&self) -> usize {
        self.inner.metrics.retunes()
    }

    /// Current queue depth (requests waiting, not yet claimed by a worker).
    pub fn queue_depth(&self) -> usize {
        self.inner.queue.len()
    }

    /// Exact queued (not yet popped) request count for one model — what
    /// its admission quota is compared against.
    pub fn model_queue_depth(&self, model: &str) -> usize {
        self.inner.queue.model_backlog(model)
    }

    /// Deepest queue observed at submit time since startup.
    pub fn peak_queue_depth(&self) -> usize {
        self.inner.metrics.peak_queue_depth()
    }

    pub fn queue_capacity(&self) -> usize {
        self.inner.queue.capacity()
    }

    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Shadow pairs begun but not yet settled (both legs still in flight
    /// somewhere). A healthy steady state hovers near zero; a monotonic
    /// climb is the pair-leak regression this gauge exists to catch.
    pub fn shadow_pending(&self) -> usize {
        self.inner.metrics.shadow_pending()
    }

    /// `(accepted, rejected, shed)` totals for the network front-end, all
    /// connections; zero until a
    /// [`Frontend`](crate::coordinator::frontend::Frontend) is attached.
    pub fn frontend_totals(&self) -> (usize, usize, usize) {
        self.inner.metrics.frontend_totals()
    }

    /// Shared metrics sink — the network front-end records its
    /// accept/reject/shed accounting here.
    pub(crate) fn metrics(&self) -> &Arc<ServingMetrics> {
        &self.inner.metrics
    }

    /// Graceful shutdown: stop accepting submits, drain every queued
    /// request (each gets its response), join all workers. Idempotent;
    /// also runs automatically when the last handle drops.
    pub fn shutdown(&self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::plan::PlanCache;

    fn demo(seed: u64, cache: Arc<PlanCache>) -> NativeSparseModel {
        NativeSparseModel::rbgp4_demo(10, 8, 2, seed, cache).unwrap()
    }

    fn demo_server(seed: u64, cache: &Arc<PlanCache>, config: ServerConfig) -> InferenceServer {
        let cache = Arc::clone(cache);
        InferenceServer::start_model(
            move || {
                let mut m = demo(seed, Arc::clone(&cache));
                m.warm()?;
                Ok(Box::new(m) as Box<dyn BatchModel>)
            },
            config,
        )
        .unwrap()
    }

    #[test]
    fn native_server_serves_and_batches() {
        let cache = Arc::new(PlanCache::new());
        let mut reference = demo(7, Arc::new(PlanCache::new()));
        let server = demo_server(
            7,
            &cache,
            ServerConfig {
                max_wait: Duration::from_millis(2),
                workers: 2,
                ..ServerConfig::default()
            },
        );
        assert_eq!(server.in_dim, 256);
        assert_eq!(server.workers(), 2);

        // Single request: result equals a padded direct forward.
        let x: Vec<f32> = (0..256).map(|i| (i as f32 / 256.0) - 0.5).collect();
        let got = server.infer(x.clone()).unwrap();
        let mut xb = vec![0.0f32; 8 * 256];
        xb[..256].copy_from_slice(&x);
        let want = reference.forward(&xb).unwrap();
        for (a, b) in got.iter().zip(&want[..10]) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }

        // A burst from several clients all gets answered.
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let server = server.clone();
                let x = x.clone();
                scope.spawn(move || {
                    for _ in 0..8 {
                        let out = server.infer(x.clone()).unwrap();
                        assert_eq!(out.len(), 10);
                    }
                });
            }
        });
        let (requests, batches) = server.counters();
        assert_eq!(requests, 33);
        assert!(batches >= 5, "at least ceil(33/8) flushes, got {batches}");
        assert!(batches <= 33, "batching never exceeds request count");
        let stats = server.latency_stats().unwrap();
        assert!(stats.occupancy > 0.0 && stats.occupancy <= 1.0);

        // Both workers warmed from one cache: exactly two structure builds
        // ever (one per layer), the second worker resolved both as hits —
        // structure derived once, executed everywhere.
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 2, "workers must share cached plans");
        assert_eq!(hits, 2, "second worker warms from cache");
    }

    #[test]
    fn submit_rejects_wrong_width() {
        let cache = Arc::new(PlanCache::new());
        let server = demo_server(3, &cache, ServerConfig::default());
        match server.submit(vec![0.0; 3]) {
            Err(ServeError::WrongInputWidth { got, want }) => {
                assert_eq!(got, 3);
                assert_eq!(want, 256);
            }
            other => panic!("expected WrongInputWidth, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn register_route_and_unregister_second_model() {
        let cache = Arc::new(PlanCache::new());
        let server = demo_server(
            1,
            &cache,
            ServerConfig {
                workers: 2,
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        );
        assert_eq!(server.models(), vec![DEFAULT_MODEL.to_string()]);
        let model_cache = Arc::clone(&cache);
        server
            .register_model("second", move || {
                let mut m = demo(2, Arc::clone(&model_cache));
                m.warm()?;
                Ok(Box::new(m) as Box<dyn BatchModel>)
            })
            .unwrap();
        assert_eq!(
            server.models(),
            vec![DEFAULT_MODEL.to_string(), "second".to_string()]
        );
        // Duplicate ids are rejected.
        assert!(server
            .register_model("second", || anyhow::bail!("never built"))
            .is_err());

        // Traffic routes by id; both models answer.
        let x = vec![0.25f32; 256];
        for _ in 0..4 {
            assert_eq!(server.infer(x.clone()).unwrap().len(), 10);
            let got = server
                .infer_with(x.clone(), SubmitOptions::default().with_model("second"))
                .unwrap();
            assert_eq!(got.len(), 10);
        }
        let stats = server.model_stats();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|m| m.requests == 4), "{stats:?}");

        // An unknown id is rejected synchronously.
        match server.infer_with(x.clone(), SubmitOptions::default().with_model("ghost")) {
            Err(ServeError::UnknownModel { model }) => assert_eq!(model, "ghost"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }

        // Unregister: two demo seeds share the dense-classifier structure
        // but own distinct RBGP4 hidden structures — exactly the retired
        // hidden namespace is evicted.
        let structures_before = cache.structures().len();
        let report = server.unregister_model("second").unwrap();
        assert_eq!(report.model, "second");
        assert_eq!(report.evicted_structures.len(), 1, "{report:?}");
        assert_eq!(report.retained_structures.len(), 1, "{report:?}");
        assert!(report.evicted_plans >= 1);
        assert_eq!(cache.structures().len(), structures_before - 1);
        assert_eq!(cache.structure_plan_count(report.evicted_structures[0]), 0);
        match server.infer_with(x.clone(), SubmitOptions::default().with_model("second")) {
            Err(ServeError::UnknownModel { .. }) => {}
            other => panic!("expected UnknownModel after unregister, got {other:?}"),
        }
        // The default model is untouched.
        assert_eq!(server.infer(x).unwrap().len(), 10);
        assert!(server.unregister_model("second").is_err(), "already gone");
        server.shutdown();
    }

    #[test]
    fn zero_deadline_gets_typed_error_and_skips_forward() {
        let cache = Arc::new(PlanCache::new());
        let server = demo_server(
            11,
            &cache,
            ServerConfig {
                workers: 1,
                ..ServerConfig::default()
            },
        );
        let x = vec![0.25f32; 256];
        // A zero deadline is expired by the time any worker pops it.
        let opts = SubmitOptions::default().with_deadline(Duration::ZERO);
        let mut receivers = Vec::new();
        for _ in 0..3 {
            receivers.push(server.submit_with(x.clone(), opts.clone()).unwrap());
        }
        for rx in receivers {
            match rx.recv().unwrap() {
                Err(ServeError::DeadlineExceeded { .. }) => {}
                other => panic!("expected DeadlineExceeded, got {other:?}"),
            }
        }
        // A live request still gets served afterwards.
        assert_eq!(server.infer(x).unwrap().len(), 10);
        let (_, late) = server.rejected();
        assert_eq!(late, 3);
        let (requests, _) = server.counters();
        assert_eq!(requests, 1, "expired requests are not served requests");
        let occupied: usize = server.worker_stats().iter().map(|w| w.occupied_slots).sum();
        assert_eq!(occupied, 1, "expired requests never occupy a batch slot");
    }

    /// A batch-1 model that blocks in `forward` until the gate channel
    /// yields (or closes) and logs every sample it computes — lets tests
    /// hold a worker busy deterministically.
    struct GatedModel {
        gate: mpsc::Receiver<()>,
        log: Arc<Mutex<Vec<f32>>>,
    }

    impl BatchModel for GatedModel {
        fn batch(&self) -> usize {
            1
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            lock_recover(&self.log).push(x[0]);
            let _ = self.gate.recv(); // blocks until the test releases (or drops) the gate
            Ok(x.to_vec())
        }
    }

    fn gated_server(
        cap: usize,
    ) -> (InferenceServer, mpsc::Sender<()>, Arc<Mutex<Vec<f32>>>) {
        gated_server_with(cap, ModelQuota::Unlimited)
    }

    fn gated_server_with(
        cap: usize,
        quota: ModelQuota,
    ) -> (InferenceServer, mpsc::Sender<()>, Arc<Mutex<Vec<f32>>>) {
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let log = Arc::new(Mutex::new(Vec::new()));
        let slot = Arc::new(Mutex::new(Some(gate_rx)));
        let factory_log = Arc::clone(&log);
        let server = InferenceServer::start_model(
            move || {
                let gate = lock_recover(&slot).take().expect("single worker");
                Ok(Box::new(GatedModel {
                    gate,
                    log: Arc::clone(&factory_log),
                }) as Box<dyn BatchModel>)
            },
            ServerConfig {
                workers: 1,
                queue_cap: cap,
                max_wait: Duration::from_millis(1),
                // These tests assert *strict* class order; age promotion
                // would reorder under a slow scheduler.
                max_starvation: None,
                model_quota: quota,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        (server, gate_tx, log)
    }

    #[test]
    fn backpressure_and_priority_order() {
        let (server, gate_tx, log) = gated_server(3);
        // Occupy the single worker: wait until it has popped the request
        // and entered forward (the log records it just before blocking).
        let rx1 = server.submit(vec![1.0]).unwrap();
        while lock_recover(&log).is_empty() {
            std::thread::yield_now();
        }
        // Worker blocked; these three sit in the queue in submit order.
        let rx_low = server
            .submit_with(vec![2.0], SubmitOptions::default().with_priority(Priority::Low))
            .unwrap();
        let rx_high = server
            .submit_with(vec![3.0], SubmitOptions::default().with_priority(Priority::High))
            .unwrap();
        let rx_norm = server.submit(vec![4.0]).unwrap();
        assert_eq!(server.queue_depth(), 3);
        // Capacity 3 reached: the next submit is told to back off.
        match server.submit(vec![5.0]) {
            Err(ServeError::QueueFull { cap }) => assert_eq!(cap, 3),
            other => panic!("expected QueueFull, got {:?}", other.map(|_| ())),
        }
        assert_eq!(server.rejected().0, 1);
        assert_eq!(server.peak_queue_depth(), 3);

        // Release the worker: dropping the gate unblocks every forward.
        drop(gate_tx);
        assert_eq!(rx1.recv().unwrap().unwrap(), vec![1.0]);
        assert_eq!(rx_high.recv().unwrap().unwrap(), vec![3.0]);
        assert_eq!(rx_norm.recv().unwrap().unwrap(), vec![4.0]);
        assert_eq!(rx_low.recv().unwrap().unwrap(), vec![2.0]);
        // The queue released them high → normal → low.
        assert_eq!(*lock_recover(&log), vec![1.0, 3.0, 4.0, 2.0]);

        // Graceful shutdown: queue rejects new work afterwards.
        server.shutdown();
        assert!(matches!(server.submit(vec![6.0]), Err(ServeError::Stopped)));
    }

    #[test]
    fn model_quota_rejects_typed_and_counts() {
        // One gated worker, default model capped to 2 queued requests on
        // a queue with room for far more.
        let (server, gate_tx, log) = gated_server_with(64, ModelQuota::Absolute(2));
        // Occupy the worker so subsequent submits stay queued.
        let rx0 = server.submit(vec![0.0]).unwrap();
        while lock_recover(&log).is_empty() {
            std::thread::yield_now();
        }
        let rx1 = server.submit(vec![1.0]).unwrap();
        let rx2 = server.submit(vec![2.0]).unwrap();
        assert_eq!(server.model_queue_depth(DEFAULT_MODEL), 2);
        // Third queued submit for the model: typed per-model rejection —
        // the shared queue (cap 64) is nowhere near full.
        match server.submit(vec![3.0]) {
            Err(ServeError::ModelQuotaExceeded { model, quota }) => {
                assert_eq!((model.as_str(), quota), (DEFAULT_MODEL, 2));
            }
            other => panic!("expected ModelQuotaExceeded, got {:?}", other.map(|_| ())),
        }
        assert_eq!(server.rejected_quota(), 1);
        assert_eq!(server.rejected(), (0, 0), "not a QueueFull rejection");
        let ms = server.model_stats();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].rejected_quota, 1);
        // Release the worker: the accepted requests all serve, and quota
        // frees as the queue drains.
        drop(gate_tx);
        for rx in [rx0, rx1, rx2] {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(server.model_queue_depth(DEFAULT_MODEL), 0);
        assert_eq!(server.infer(vec![4.0]).unwrap(), vec![4.0]);
        server.shutdown();
    }

    /// A model that panics on a poison-pill sample — simulates a worker
    /// crashing mid-batch.
    struct PanickyModel;

    impl BatchModel for PanickyModel {
        fn batch(&self) -> usize {
            1
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            assert!(x[0] < 0.5, "poison pill");
            Ok(x.to_vec())
        }
    }

    #[test]
    fn crashed_worker_degrades_metrics_instead_of_poisoning_clients() {
        let server = InferenceServer::start_model(
            || Ok(Box::new(PanickyModel) as Box<dyn BatchModel>),
            ServerConfig {
                workers: 2,
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        )
        .unwrap();
        // Serve some normal traffic first so there are recorded samples.
        for _ in 0..4 {
            assert_eq!(server.infer(vec![0.0]).unwrap(), vec![0.0]);
        }
        // The pill kills whichever worker pops it; the client sees a
        // dropped request, not a panic.
        assert!(matches!(server.infer(vec![1.0]), Err(ServeError::Stopped)));
        // Metrics must keep answering — the old Arc<Mutex<Metrics>> store
        // would panic here if the dead worker had poisoned it.
        let stats = server.latency_stats().expect("samples recorded");
        assert_eq!(stats.count, 4);
        let (requests, _) = server.counters();
        assert_eq!(requests, 4);
        // The surviving worker keeps serving.
        assert_eq!(server.infer(vec![0.25]).unwrap(), vec![0.25]);
        server.shutdown();
    }

    #[test]
    fn dead_pool_fails_fast_instead_of_hanging() {
        let server = InferenceServer::start_model(
            || Ok(Box::new(PanickyModel) as Box<dyn BatchModel>),
            ServerConfig {
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        )
        .unwrap();
        // The pill kills the only worker.
        assert!(matches!(server.infer(vec![1.0]), Err(ServeError::Stopped)));
        // Every later request must fail fast with the typed error — either
        // rejected at submit (the dying worker's guard closed the queue) or
        // drained with `Stopped` — never parked on a receiver forever.
        for _ in 0..3 {
            assert!(matches!(server.infer(vec![0.0]), Err(ServeError::Stopped)));
        }
        assert!(server.latency_stats().is_none(), "nothing was ever served");
    }

    #[test]
    fn retired_default_model_rejects_typed_not_panicking() {
        // Regression: an alias-less submit resolves DEFAULT_MODEL; after
        // the default is retired that must be the typed UnknownModel —
        // never a panic in resolution.
        let cache = Arc::new(PlanCache::new());
        let server = demo_server(
            5,
            &cache,
            ServerConfig {
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        );
        let c2 = Arc::clone(&cache);
        server
            .register_model("v2", move || {
                let mut m = demo(6, Arc::clone(&c2));
                m.warm()?;
                Ok(Box::new(m) as Box<dyn BatchModel>)
            })
            .unwrap();
        server.unregister_model(DEFAULT_MODEL).unwrap();
        match server.submit(vec![0.0; 256]) {
            Err(ServeError::UnknownModel { model }) => assert_eq!(model, DEFAULT_MODEL),
            other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
        }
        // The surviving model keeps serving by explicit id.
        let got = server
            .infer_with(vec![0.25; 256], SubmitOptions::default().with_model("v2"))
            .unwrap();
        assert_eq!(got.len(), 10);
        server.shutdown();
    }

    #[test]
    fn alias_routes_and_rollout_retires_old_primary() {
        let cache = Arc::new(PlanCache::new());
        let server = demo_server(
            9,
            &cache,
            ServerConfig {
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        );
        server.set_alias("prod", DEFAULT_MODEL).unwrap();
        let x = vec![0.25f32; 256];
        let direct = server.infer(x.clone()).unwrap();
        let via_alias = server
            .infer_with(x.clone(), SubmitOptions::default().with_model("prod"))
            .unwrap();
        assert_eq!(direct, via_alias, "an alias is a pure rename");
        let stats = server.alias_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!((stats[0].alias.as_str(), stats[0].requests), ("prod", 1));
        assert_eq!(server.alias_target("prod").as_deref(), Some(DEFAULT_MODEL));

        // Stage v2, then roll out: flip + drain + retire in one call.
        let c2 = Arc::clone(&cache);
        server
            .register_model("v2", move || {
                let mut m = demo(10, Arc::clone(&c2));
                m.warm()?;
                Ok(Box::new(m) as Box<dyn BatchModel>)
            })
            .unwrap();
        let report = server.rollout("prod", "v2").unwrap();
        assert_eq!(report.model, DEFAULT_MODEL);
        // The two demo seeds share the dense-classifier structure but own
        // distinct hidden structures: exactly the old one is evicted.
        assert_eq!(report.evicted_structures.len(), 1, "{report:?}");
        assert_eq!(report.retained_structures.len(), 1, "{report:?}");
        // prod answers from v2; the old primary is unreachable, and the
        // alias-less path (satellite of the same fix) is typed too.
        assert_eq!(
            server
                .infer_with(x.clone(), SubmitOptions::default().with_model("prod"))
                .unwrap()
                .len(),
            10
        );
        match server.submit(x) {
            Err(ServeError::UnknownModel { model }) => assert_eq!(model, DEFAULT_MODEL),
            other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
        }
        assert!(server.rollout("prod", "v2").is_err(), "nothing to roll out");
        assert_eq!(server.rejected(), (0, 0), "rollout drops nothing");
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let (server, gate_tx, log) = gated_server(64);
        let rx_first = server.submit(vec![10.0]).unwrap();
        while lock_recover(&log).is_empty() {
            std::thread::yield_now();
        }
        let pending: Vec<_> = (0..5)
            .map(|i| server.submit(vec![i as f32]).unwrap())
            .collect();
        // Release the worker and shut down concurrently with the drain:
        // every queued request must still receive its answer.
        drop(gate_tx);
        server.shutdown();
        assert_eq!(rx_first.recv().unwrap().unwrap(), vec![10.0]);
        for (i, rx) in pending.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
        assert!(matches!(server.submit(vec![0.0]), Err(ServeError::Stopped)));
    }

    #[test]
    fn fairshare_quota_shrinks_when_third_model_registers() {
        // Regression: fair-share caps were resolved once at registration,
        // so later registrations left the hot model's limit stale at its
        // sole-model share. The effective cap must shrink as membership
        // grows — observable end to end as the quota in the typed error.
        let (server, gate_tx, log) = gated_server_with(16, ModelQuota::FairShare(0.5));
        // Occupy the single worker so submits stay queued.
        let rx0 = server.submit(vec![0.0]).unwrap();
        while lock_recover(&log).is_empty() {
            std::thread::yield_now();
        }
        // Sole model: cap = 0.5 × 16 = 8, so five queued submits all fit.
        let pending: Vec<_> = (0..5)
            .map(|i| server.submit(vec![i as f32]).unwrap())
            .collect();
        assert_eq!(server.model_queue_depth(DEFAULT_MODEL), 5);

        // A second model halves the share (8 / 2 = 4): the backlog of 5
        // already exceeds the shrunk cap, so the next submit is rejected
        // with the *current* limit. Already-queued entries are never
        // evicted by a shrink.
        server
            .register_model("cold", || Ok(Box::new(PanickyModel) as Box<dyn BatchModel>))
            .unwrap();
        match server.submit(vec![9.0]) {
            Err(ServeError::ModelQuotaExceeded { model, quota }) => {
                assert_eq!((model.as_str(), quota), (DEFAULT_MODEL, 4));
            }
            other => panic!("expected ModelQuotaExceeded, got {:?}", other.map(|_| ())),
        }
        // A third registration shrinks it again (8 / 3 = 2).
        server
            .register_model("cold2", || Ok(Box::new(PanickyModel) as Box<dyn BatchModel>))
            .unwrap();
        match server.submit(vec![9.0]) {
            Err(ServeError::ModelQuotaExceeded { quota, .. }) => assert_eq!(quota, 2),
            other => panic!("expected ModelQuotaExceeded, got {:?}", other.map(|_| ())),
        }

        drop(gate_tx);
        assert!(rx0.recv().unwrap().is_ok());
        for rx in pending {
            assert!(rx.recv().unwrap().is_ok());
        }
        server.shutdown();
    }

    /// A model whose forward always fails — a shadow candidate that dies
    /// with a Backend error on every mirrored request.
    struct AlwaysFailingModel;

    impl BatchModel for AlwaysFailingModel {
        fn batch(&self) -> usize {
            1
        }
        fn in_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn forward(&mut self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("candidate kernel exploded")
        }
    }

    #[test]
    fn failing_shadow_candidate_settles_every_pair_no_leak() {
        // Regression: a ShadowPair whose mirror leg died with a Backend
        // error never received its second deposit and was retained
        // forever. Pairs must settle complete-or-expire: the incomplete
        // pair counts as shadow_dropped and its slot frees — under
        // sustained shadow traffic the pending gauge returns to zero.
        struct EchoModel;
        impl BatchModel for EchoModel {
            fn batch(&self) -> usize {
                1
            }
            fn in_dim(&self) -> usize {
                1
            }
            fn classes(&self) -> usize {
                1
            }
            fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
                Ok(x.to_vec())
            }
        }
        let server = InferenceServer::start_model(
            || Ok(Box::new(EchoModel) as Box<dyn BatchModel>),
            ServerConfig {
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        )
        .unwrap();
        server
            .register_model("bad", || Ok(Box::new(AlwaysFailingModel) as Box<dyn BatchModel>))
            .unwrap();
        server.set_alias("prod", DEFAULT_MODEL).unwrap();
        server.set_shadow("prod", "bad").unwrap();

        // Sustained shadow traffic: every primary answers, every mirror
        // leg dies in the candidate's forward.
        for i in 0..32 {
            let got = server
                .infer_with(vec![i as f32], SubmitOptions::default().with_model("prod"))
                .unwrap();
            assert_eq!(got, vec![i as f32], "clients always answered by the primary");
        }
        // Shutdown drains the remaining Low-priority mirrors; afterwards
        // every pair must have settled — no pair-map growth.
        server.shutdown();
        assert_eq!(server.shadow_pending(), 0, "no leaked shadow pairs");
        let stats = server.alias_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].alias, "prod");
        assert_eq!(
            stats[0].shadow_dropped, 32,
            "every incomplete pair is counted exactly once"
        );
    }
}
