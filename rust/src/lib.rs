//! # RBGP — Ramanujan Bipartite Graph Products for Block Sparse Networks
//!
//! Rust + JAX + Pallas reproduction of Vooturi, Varma & Kothapalli (2020).
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.
//!
//! Layer map:
//! * [`graph`] / [`sparsity`] — the paper's §3–§4 theory: Ramanujan graph
//!   generation by 2-lifts, graph products, RCUBS patterns, RBGP4 masks.
//! * [`kernels`] — measured CPU SDMM kernels (dense/CSR/BSR/RBGP4MM).
//! * [`gpusim`] — V100 roofline cost model (the paper's testbed stand-in).
//! * [`models`] / [`data`] — VGG19 & WRN-40-4 shape descriptions, synthetic
//!   CIFAR-like data.
//! * [`runtime`] / [`coordinator`] — PJRT artifact execution and the
//!   training/serving drivers (Python never runs at request time).
//! * [`bench_harness`] — regenerates every table of the paper's evaluation.

pub mod analysis;
pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod gpusim;
pub mod graph;
pub mod kernels;
pub mod models;
pub mod runtime;
pub mod sparsity;
pub mod train_native;
pub mod util;
