//! Block sparsity pattern definitions and validators (§3 of the paper).
//!
//! These operate on dense 0/1 masks (row-major `f32`, nonzero = connected).
//! They are the *specification* side of the library: property tests assert
//! that every mask produced by the RBGP constructions satisfies the exact
//! pattern class the paper claims (CBS/CUBS from one product, RCUBS from
//! chains).

/// A block size `(bh, bw)`.
pub type Block = (usize, usize);

fn block_grid(rows: usize, cols: usize, (bh, bw): Block) -> anyhow::Result<(usize, usize)> {
    anyhow::ensure!(bh > 0 && bw > 0, "zero block size");
    anyhow::ensure!(
        rows % bh == 0 && cols % bw == 0,
        "{rows}x{cols} not divisible by block {bh}x{bw}"
    );
    Ok((rows / bh, cols / bw))
}

/// Is block `(bi, bj)` entirely zero?
fn block_is_zero(mask: &[f32], cols: usize, (bh, bw): Block, bi: usize, bj: usize) -> bool {
    for i in 0..bh {
        let row = (bi * bh + i) * cols + bj * bw;
        if mask[row..row + bw].iter().any(|&x| x != 0.0) {
            return false;
        }
    }
    true
}

/// Extract block `(bi, bj)` as a 0/1 pattern vector.
fn block_pattern(mask: &[f32], cols: usize, (bh, bw): Block, bi: usize, bj: usize) -> Vec<bool> {
    let mut p = Vec::with_capacity(bh * bw);
    for i in 0..bh {
        let row = (bi * bh + i) * cols + bj * bw;
        p.extend(mask[row..row + bw].iter().map(|&x| x != 0.0));
    }
    p
}

/// **BS**: every matrix is trivially block sparse for a block size that
/// divides it; this just checks divisibility (the paper's definition imposes
/// no constraint beyond the block grid existing).
pub fn is_bs(rows: usize, cols: usize, block: Block) -> bool {
    block_grid(rows, cols, block).is_ok()
}

/// **UBS**: all row-blocks have the same number of non-zero blocks, and all
/// column-blocks have the same number of non-zero blocks.
pub fn is_ubs(mask: &[f32], rows: usize, cols: usize, block: Block) -> anyhow::Result<bool> {
    let (gm, gn) = block_grid(rows, cols, block)?;
    let mut row_counts = vec![0usize; gm];
    let mut col_counts = vec![0usize; gn];
    for bi in 0..gm {
        for bj in 0..gn {
            if !block_is_zero(mask, cols, block, bi, bj) {
                row_counts[bi] += 1;
                col_counts[bj] += 1;
            }
        }
    }
    Ok(row_counts.windows(2).all(|w| w[0] == w[1]) && col_counts.windows(2).all(|w| w[0] == w[1]))
}

/// **CBS**: all non-zero blocks share one identical non-zero pattern.
pub fn is_cbs(mask: &[f32], rows: usize, cols: usize, block: Block) -> anyhow::Result<bool> {
    let (gm, gn) = block_grid(rows, cols, block)?;
    let mut clone: Option<Vec<bool>> = None;
    for bi in 0..gm {
        for bj in 0..gn {
            if block_is_zero(mask, cols, block, bi, bj) {
                continue;
            }
            let p = block_pattern(mask, cols, block, bi, bj);
            match &clone {
                None => clone = Some(p),
                Some(c) => {
                    if *c != p {
                        return Ok(false);
                    }
                }
            }
        }
    }
    Ok(true)
}

/// **CUBS** = UBS ∧ CBS at the same block size.
pub fn is_cubs(mask: &[f32], rows: usize, cols: usize, block: Block) -> anyhow::Result<bool> {
    Ok(is_ubs(mask, rows, cols, block)? && is_cbs(mask, rows, cols, block)?)
}

/// **RCUBS** with blocking levels `B_1 > B_2 > … > B_K`: the mask is CUBS at
/// `B_1`, and the (shared) non-zero block pattern at level `i` is itself CUBS
/// at `B_{i+1}`, recursively. Because all non-zero blocks are clones, it
/// suffices to recurse into *one* representative non-zero block per level.
pub fn is_rcubs(
    mask: &[f32],
    rows: usize,
    cols: usize,
    levels: &[Block],
) -> anyhow::Result<bool> {
    anyhow::ensure!(!levels.is_empty(), "RCUBS needs at least one level");
    // Validate level nesting: each level must divide the previous.
    let mut prev = (rows, cols);
    for &(bh, bw) in levels {
        anyhow::ensure!(
            prev.0 % bh == 0 && prev.1 % bw == 0,
            "level ({bh},{bw}) does not divide enclosing ({},{})",
            prev.0,
            prev.1
        );
        prev = (bh, bw);
    }

    let block = levels[0];
    if !is_cubs(mask, rows, cols, block)? {
        return Ok(false);
    }
    if levels.len() == 1 {
        return Ok(true);
    }
    // Find one non-zero block and recurse into it.
    let (gm, gn) = block_grid(rows, cols, block)?;
    for bi in 0..gm {
        for bj in 0..gn {
            if block_is_zero(mask, cols, block, bi, bj) {
                continue;
            }
            let (bh, bw) = block;
            let mut sub = vec![0.0f32; bh * bw];
            for i in 0..bh {
                let row = (bi * bh + i) * cols + bj * bw;
                sub[i * bw..(i + 1) * bw].copy_from_slice(&mask[row..row + bw]);
            }
            return is_rcubs(&sub, bh, bw, &levels[1..]);
        }
    }
    Ok(true) // all-zero mask is vacuously RCUBS
}

/// **Row repetition** (§5, "Row repetition"): rows split into `groups` groups
/// of equal size where all rows in a group have non-zeros at identical
/// locations. The RBGP4 grouping interleaves: row `u`'s group is determined
/// by its `G_i`-coordinate, i.e. group id = `(u / m_b) % m_i` when rows
/// factor as `(u_r, u_i, u_b)`. This checks the generic property: there
/// exists a partition into `groups` classes by identical pattern, each of
/// size `rows/groups`.
pub fn row_repetition_groups(mask: &[f32], rows: usize, cols: usize) -> Vec<usize> {
    use std::collections::HashMap;
    let mut ids: HashMap<&[u8], usize> = HashMap::new();
    let mut group_of = Vec::with_capacity(rows);
    // Compare rows bytewise on the 0/1 pattern.
    let patterns: Vec<Vec<u8>> = (0..rows)
        .map(|r| {
            mask[r * cols..(r + 1) * cols]
                .iter()
                .map(|&x| (x != 0.0) as u8)
                .collect()
        })
        .collect();
    for p in &patterns {
        let next = ids.len();
        let id = *ids.entry(p.as_slice()).or_insert(next);
        group_of.push(id);
    }
    group_of
}

/// Number of distinct row patterns.
pub fn num_row_groups(mask: &[f32], rows: usize, cols: usize) -> usize {
    let g = row_repetition_groups(mask, rows, cols);
    g.iter().copied().max().map(|m| m + 1).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::bipartite::BipartiteGraph;
    use crate::graph::product::product_many;
    use crate::util::rng::Rng;

    /// 4x4 mask with 2x2 blocks: one zero block, others dense → UBS fails
    /// (row 0 has 2 blocks, row 1 has 1), CBS holds (all non-zero blocks dense).
    #[test]
    fn ubs_cbs_disagree() {
        #[rustfmt::skip]
        let mask = vec![
            1., 1., 1., 1.,
            1., 1., 1., 1.,
            1., 1., 0., 0.,
            1., 1., 0., 0.,
        ];
        assert!(!is_ubs(&mask, 4, 4, (2, 2)).unwrap());
        assert!(is_cbs(&mask, 4, 4, (2, 2)).unwrap());
    }

    #[test]
    fn cbs_detects_pattern_mismatch() {
        #[rustfmt::skip]
        let mask = vec![
            1., 0., 0., 1.,
            0., 1., 1., 0.,
            0., 0., 0., 0.,
            0., 0., 0., 0.,
        ];
        // Two non-zero 2x2 blocks with different patterns.
        assert!(!is_cbs(&mask, 4, 4, (2, 2)).unwrap());
    }

    #[test]
    fn diagonal_blocks_are_cubs() {
        #[rustfmt::skip]
        let mask = vec![
            1., 1., 0., 0.,
            1., 1., 0., 0.,
            0., 0., 1., 1.,
            0., 0., 1., 1.,
        ];
        assert!(is_cubs(&mask, 4, 4, (2, 2)).unwrap());
    }

    #[test]
    fn product_of_graphs_is_cbs_figure2() {
        // §4 "Structured sparsity": BA_p = BA_1 ⊗ BA_2 is CBS with block
        // size (|G_2.U|, |G_2.V|); CUBS when G_1 is biregular.
        let mut rng = Rng::new(21);
        let g1 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
        let g2 = BipartiteGraph::random_biregular(4, 2, 1, &mut rng).unwrap();
        let p = crate::graph::product::product(&g1, &g2);
        let ba = p.biadjacency();
        assert!(is_cbs(&ba, p.nu, p.nv, (g2.nu, g2.nv)).unwrap());
        assert!(is_cubs(&ba, p.nu, p.nv, (g2.nu, g2.nv)).unwrap());
    }

    #[test]
    fn figure3_rcubs_three_levels() {
        // Figure 3: four base graphs, blocking levels (16,16), (8,8), (2,2).
        // Base sizes: G1 (2x2, d=2? no) — paper: 512 edges = 8*2*8*4 with
        // base edge counts 8+2+8+4. Use G1: 4x4 d_l=2 (8 edges),
        // G2: 2x2 d=1 (2 edges), G3: 4x4 d=2 (8 edges), G4: 2x2 complete (4).
        let mut rng = Rng::new(33);
        let g1 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
        let g2 = BipartiteGraph::identity(2);
        let g3 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
        let g4 = BipartiteGraph::complete(2, 2);
        let p = product_many(&[&g1, &g2, &g3, &g4]).unwrap();
        assert_eq!((p.nu, p.nv), (64, 64));
        assert_eq!(p.num_edges(), 8 * 2 * 8 * 4); // 512 as in the paper
        let ba = p.biadjacency();
        // Levels B_j = (prod_{i>j} |G_i.U|, prod |G_i.V|): (16,16), (8,8), (2,2).
        assert!(is_rcubs(&ba, 64, 64, &[(16, 16), (8, 8), (2, 2)]).unwrap());
        // And a wrong level chain must fail on a sparse pattern: level (4,4)
        // inside the (8,8) block of this chain is not CUBS in general; verify
        // the validator can say "no" for a broken mask instead:
        let mut broken = ba.clone();
        // Find a nonzero and zero it — breaks clone uniformity at last level.
        let idx = broken.iter().position(|&x| x != 0.0).unwrap();
        broken[idx] = 0.0;
        assert!(!is_rcubs(&broken, 64, 64, &[(16, 16), (8, 8), (2, 2)]).unwrap());
    }

    #[test]
    fn rcubs_rejects_bad_level_nesting() {
        let mask = vec![1.0; 16];
        assert!(is_rcubs(&mask, 4, 4, &[(2, 2), (3, 3)]).is_err());
        assert!(is_rcubs(&mask, 4, 4, &[]).is_err());
    }

    #[test]
    fn row_groups_counts_distinct_patterns() {
        #[rustfmt::skip]
        let mask = vec![
            1., 0.,
            1., 0.,
            0., 1.,
            1., 0.,
        ];
        assert_eq!(num_row_groups(&mask, 4, 2), 2);
        let g = row_repetition_groups(&mask, 4, 2);
        assert_eq!(g, vec![0, 0, 1, 0]);
    }

    #[test]
    fn complete_mask_everything_holds() {
        let mask = vec![1.0f32; 8 * 8];
        assert!(is_ubs(&mask, 8, 8, (2, 2)).unwrap());
        assert!(is_cbs(&mask, 8, 8, (2, 2)).unwrap());
        assert!(is_rcubs(&mask, 8, 8, &[(4, 4), (2, 2)]).unwrap());
        assert_eq!(num_row_groups(&mask, 8, 8), 1);
    }
}
