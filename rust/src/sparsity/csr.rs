//! CSR (compressed sparse row) format — the *unstructured* baseline.
//!
//! This is the stand-in for cuSparse's CSR: the format the paper benchmarks
//! "Unstructured" rows of Table 1 against. Masks for unstructured baselines
//! are sampled with row uniformity (equal non-zeros per row, matching how
//! the paper's predefined approach assigns equal sparsity per layer).

use crate::util::rng::Rng;

/// CSR matrix with f32 values and usize indices.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, length rows + 1.
    pub indptr: Vec<usize>,
    /// Column indices, ascending within each row.
    pub indices: Vec<usize>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from a dense row-major matrix, keeping exact non-zeros.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> CsrMatrix {
        assert_eq!(dense.len(), rows * cols);
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let v = dense[r * cols + c];
                if v != 0.0 {
                    indices.push(c);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Build from a dense row-major matrix, taking the *pattern* from a 0/1
    /// `mask` (same shape) and the values from `dense`. Unlike
    /// [`CsrMatrix::from_dense`], an on-mask weight that happens to be
    /// exactly `0.0` is stored explicitly, so the CSR pattern — and hence
    /// the structure hash the plan cache keys on — is a function of the
    /// mask alone, not of transient weight values. This is what keeps a
    /// trainer's structure hash stable *within* a mask milestone and makes
    /// it change exactly *at* one.
    pub fn from_dense_with_pattern(
        dense: &[f32],
        mask: &[f32],
        rows: usize,
        cols: usize,
    ) -> CsrMatrix {
        assert_eq!(dense.len(), rows * cols);
        assert_eq!(mask.len(), rows * cols);
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                if mask[r * cols + c] != 0.0 {
                    indices.push(c);
                    values.push(dense[r * cols + c]);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Random unstructured mask with row uniformity: each row gets exactly
    /// `round((1-sp)*cols)` non-zeros at uniformly random distinct columns,
    /// with standard-normal values scaled like the RBGP init.
    pub fn random_row_uniform(rows: usize, cols: usize, sp: f64, rng: &mut Rng) -> CsrMatrix {
        let nnz_row = (((1.0 - sp) * cols as f64).round() as usize).max(1);
        let scale = (2.0 / nnz_row as f64).sqrt() as f32;
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::with_capacity(rows * nnz_row);
        let mut values = Vec::with_capacity(rows * nnz_row);
        indptr.push(0);
        for _ in 0..rows {
            let mut cols_r = rng.sample_indices(cols, nnz_row);
            cols_r.sort_unstable();
            for c in cols_r {
                indices.push(c);
                values.push(rng.normal_f32() * scale);
            }
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                d[r * self.cols + self.indices[k]] = self.values[k];
            }
        }
        d
    }

    /// Storage bytes: values f32 + indices i32 + indptr i32 — the layout
    /// cuSparse uses (and what the paper's Table 1 "Mem" column counts for
    /// unstructured: 2·|E| with 4-byte value + 4-byte index per edge;
    /// indptr is negligible and excluded to match the paper's accounting).
    pub fn storage_bytes_paper(&self) -> u64 {
        (self.nnz() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dense_roundtrip() {
        #[rustfmt::skip]
        let d = vec![
            1., 0., 2.,
            0., 0., 0.,
            0., 3., 0.,
        ];
        let m = CsrMatrix::from_dense(&d, 3, 3);
        assert_eq!(m.indptr, vec![0, 2, 2, 3]);
        assert_eq!(m.indices, vec![0, 2, 1]);
        assert_eq!(m.values, vec![1., 2., 3.]);
        assert_eq!(m.to_dense(), d);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn from_dense_with_pattern_keeps_explicit_zeros() {
        #[rustfmt::skip]
        let dense = vec![
            1., 0., 2.,
            0., 0., 0.,
            0., 3., 0.,
        ];
        #[rustfmt::skip]
        let mask = vec![
            1., 0., 1.,
            1., 0., 0.,
            0., 1., 0.,
        ];
        let m = CsrMatrix::from_dense_with_pattern(&dense, &mask, 3, 3);
        // The zero weight at (1,0) is on the mask → stored explicitly.
        assert_eq!(m.indptr, vec![0, 2, 3, 4]);
        assert_eq!(m.indices, vec![0, 2, 0, 1]);
        assert_eq!(m.values, vec![1., 2., 0., 3.]);
        assert_eq!(m.to_dense(), dense, "explicit zeros scatter back to zero");
        // Pattern is mask-determined: zeroing a masked-in value changes the
        // values, never the indices (the structure hash's input).
        let mut d2 = dense.clone();
        d2[0] = 0.0;
        let m2 = CsrMatrix::from_dense_with_pattern(&d2, &mask, 3, 3);
        assert_eq!(m2.indptr, m.indptr);
        assert_eq!(m2.indices, m.indices);
    }

    #[test]
    fn random_row_uniform_properties() {
        let mut rng = Rng::new(5);
        let m = CsrMatrix::random_row_uniform(16, 32, 0.75, &mut rng);
        assert_eq!(m.nnz(), 16 * 8);
        assert!((m.sparsity() - 0.75).abs() < 1e-12);
        for r in 0..16 {
            let row = &m.indices[m.indptr[r]..m.indptr[r + 1]];
            assert_eq!(row.len(), 8);
            assert!(row.windows(2).all(|w| w[0] < w[1]));
            assert!(row.iter().all(|&c| c < 32));
        }
    }

    #[test]
    fn storage_accounting() {
        let mut rng = Rng::new(6);
        let m = CsrMatrix::random_row_uniform(8, 8, 0.5, &mut rng);
        assert_eq!(m.storage_bytes_paper(), (8 * 4 * 8) as u64);
    }
}
