//! BSR (block sparse row) format — the *block* baseline (Table 1 "Block").
//!
//! Stand-in for cuSparse's BSR with block size (4,4), the configuration the
//! paper benchmarks. Non-zero blocks are stored densely; the index cost is
//! one column index per block, which is where block sparsity's 2× memory
//! win over unstructured comes from.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct BsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bh: usize,
    pub bw: usize,
    /// Block-row pointers, length rows/bh + 1.
    pub indptr: Vec<usize>,
    /// Block-column indices, ascending within a block row.
    pub indices: Vec<usize>,
    /// Dense block contents, `indices.len() * bh * bw`, block-major then
    /// row-major inside the block.
    pub values: Vec<f32>,
}

impl BsrMatrix {
    pub fn block_rows(&self) -> usize {
        self.rows / self.bh
    }

    pub fn block_cols(&self) -> usize {
        self.cols / self.bw
    }

    pub fn num_blocks(&self) -> usize {
        self.indices.len()
    }

    /// Build from dense, keeping any block that contains a non-zero.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize, bh: usize, bw: usize) -> BsrMatrix {
        assert_eq!(dense.len(), rows * cols);
        assert!(rows % bh == 0 && cols % bw == 0, "block must divide shape");
        let (gm, gn) = (rows / bh, cols / bw);
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for bi in 0..gm {
            for bj in 0..gn {
                let mut any = false;
                'scan: for i in 0..bh {
                    let row = (bi * bh + i) * cols + bj * bw;
                    if dense[row..row + bw].iter().any(|&x| x != 0.0) {
                        any = true;
                        break 'scan;
                    }
                }
                if any {
                    indices.push(bj);
                    for i in 0..bh {
                        let row = (bi * bh + i) * cols + bj * bw;
                        values.extend_from_slice(&dense[row..row + bw]);
                    }
                }
            }
            indptr.push(indices.len());
        }
        BsrMatrix {
            rows,
            cols,
            bh,
            bw,
            indptr,
            indices,
            values,
        }
    }

    /// Random block mask with block-row uniformity: each block row gets
    /// exactly `round((1-sp)*block_cols)` non-zero blocks (dense inside).
    pub fn random_block_uniform(
        rows: usize,
        cols: usize,
        bh: usize,
        bw: usize,
        sp: f64,
        rng: &mut Rng,
    ) -> BsrMatrix {
        assert!(rows % bh == 0 && cols % bw == 0);
        let (gm, gn) = (rows / bh, cols / bw);
        let nblk_row = (((1.0 - sp) * gn as f64).round() as usize).max(1);
        let fan_in = nblk_row * bw;
        let scale = (2.0 / fan_in as f64).sqrt() as f32;
        let mut indptr = vec![0usize];
        let mut indices = Vec::with_capacity(gm * nblk_row);
        let mut values = Vec::with_capacity(gm * nblk_row * bh * bw);
        for _ in 0..gm {
            let mut bcols = rng.sample_indices(gn, nblk_row);
            bcols.sort_unstable();
            for bj in bcols {
                indices.push(bj);
                for _ in 0..bh * bw {
                    values.push(rng.normal_f32() * scale);
                }
            }
            indptr.push(indices.len());
        }
        BsrMatrix {
            rows,
            cols,
            bh,
            bw,
            indptr,
            indices,
            values,
        }
    }

    pub fn nnz_stored(&self) -> usize {
        self.values.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz_stored() as f64 / (self.rows * self.cols) as f64
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0.0f32; self.rows * self.cols];
        for bi in 0..self.block_rows() {
            for (slot, k) in (self.indptr[bi]..self.indptr[bi + 1]).enumerate() {
                let _ = slot;
                let bj = self.indices[k];
                let blk = &self.values[k * self.bh * self.bw..(k + 1) * self.bh * self.bw];
                for i in 0..self.bh {
                    let row = (bi * self.bh + i) * self.cols + bj * self.bw;
                    d[row..row + self.bw].copy_from_slice(&blk[i * self.bw..(i + 1) * self.bw]);
                }
            }
        }
        d
    }

    /// Storage bytes: stored values + one 4-byte index per block — the
    /// paper's Table-1 "Block" memory accounting (values dominate; the per-
    /// block index is the 1/(bh·bw) overhead vs. the pure parameter count).
    pub fn storage_bytes_paper(&self) -> u64 {
        (self.nnz_stored() * 4 + self.num_blocks() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dense_roundtrip() {
        #[rustfmt::skip]
        let d = vec![
            1., 2., 0., 0.,
            3., 4., 0., 0.,
            0., 0., 0., 5.,
            0., 0., 6., 0.,
        ];
        let m = BsrMatrix::from_dense(&d, 4, 4, 2, 2);
        assert_eq!(m.num_blocks(), 2);
        assert_eq!(m.indptr, vec![0, 1, 2]);
        assert_eq!(m.indices, vec![0, 1]);
        assert_eq!(m.to_dense(), d);
    }

    #[test]
    fn random_block_uniform_properties() {
        let mut rng = Rng::new(9);
        let m = BsrMatrix::random_block_uniform(16, 16, 4, 4, 0.75, &mut rng);
        assert_eq!(m.num_blocks(), 4 * 1);
        assert!((m.sparsity() - 0.75).abs() < 1e-12);
        let d = m.to_dense();
        let back = BsrMatrix::from_dense(&d, 16, 16, 4, 4);
        assert_eq!(back.indices, m.indices);
    }

    #[test]
    fn storage_accounting() {
        let mut rng = Rng::new(10);
        let m = BsrMatrix::random_block_uniform(8, 8, 4, 4, 0.5, &mut rng);
        // 2 block rows x 1 block each x 16 values = 32 values + 2 indices.
        assert_eq!(m.storage_bytes_paper(), (32 * 4 + 2 * 4) as u64);
    }
}
