//! RBGP4 sparsity pattern (§5): `G = G_o ⊗_b G_r ⊗_b G_i ⊗_b G_b` with
//! `G_o`, `G_i` sparse Ramanujan graphs and `G_r`, `G_b` complete.
//!
//! This module defines the *single* contract format every consumer uses:
//! the Rust kernels, the GPU cost model, the Pallas kernel and the jnp
//! oracle all read the same `(data, adj_o, adj_i)` compact representation:
//!
//! * `data` — `(rows, row_nnz)` row-major dense array holding, for each row,
//!   its non-zero weights in ascending column order (possible because the
//!   product graph is biregular — every row has exactly `row_nnz` non-zeros).
//! * `adj_o` — `(m_o, d_o)` tile-level adjacency of `G_o`.
//! * `adj_i` — `(m_i, d_i)` intra-tile adjacency of `G_i`.
//!
//! Index memory is therefore `Σ|E(G_i)|` (succinct representation of §4)
//! instead of `|E(G)|`.

use crate::graph::bipartite::BipartiteGraph;
use crate::graph::product::product_many;
use crate::graph::ramanujan;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Size + sparsity of one sparse base graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GraphSpec {
    pub nu: usize,
    pub nv: usize,
    /// Dyadic sparsity in [0, 1): 0, 1/2, 3/4, 7/8, …
    pub sp: f64,
}

impl GraphSpec {
    pub fn new(nu: usize, nv: usize, sp: f64) -> GraphSpec {
        GraphSpec { nu, nv, sp }
    }

    /// Left degree of the biregular graph this spec generates.
    pub fn dl(&self) -> usize {
        ((1.0 - self.sp) * self.nv as f64).round() as usize
    }
}

/// Full RBGP4 configuration: sizes of the four base graphs and sparsities of
/// the two sparse ones. `G_r` and `G_b` are complete by definition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rbgp4Config {
    pub go: GraphSpec,
    /// (|G_r.U|, |G_r.V|) — complete.
    pub gr: (usize, usize),
    pub gi: GraphSpec,
    /// (|G_b.U|, |G_b.V|) — complete.
    pub gb: (usize, usize),
}

impl Rbgp4Config {
    /// The paper's running example (§5 "RBGP4 runtime characteristics"):
    /// sizes (32,128),(4,1),(32,32),(1,1) with the given (sp_o, sp_i).
    pub fn paper_default(sp_o: f64, sp_i: f64) -> Rbgp4Config {
        Rbgp4Config {
            go: GraphSpec::new(32, 128, sp_o),
            gr: (4, 1),
            gi: GraphSpec::new(32, 32, sp_i),
            gb: (1, 1),
        }
    }

    pub fn rows(&self) -> usize {
        self.go.nu * self.gr.0 * self.gi.nu * self.gb.0
    }

    pub fn cols(&self) -> usize {
        self.go.nv * self.gr.1 * self.gi.nv * self.gb.1
    }

    /// Tile height `TM = |G_t.U|` where `G_t = G_r ⊗ G_i ⊗ G_b`.
    pub fn tile_m(&self) -> usize {
        self.gr.0 * self.gi.nu * self.gb.0
    }

    /// Tile width `TK = |G_t.V|`.
    pub fn tile_k(&self) -> usize {
        self.gr.1 * self.gi.nv * self.gb.1
    }

    /// Tile-level left degree `d_o` (non-zero tiles per row of tiles).
    pub fn d_o(&self) -> usize {
        self.go.dl()
    }

    /// Intra-tile left degree of `G_i`.
    pub fn d_i(&self) -> usize {
        self.gi.dl()
    }

    /// Non-zeros per row *within* one non-zero tile: `n_r · d_i · n_b`.
    pub fn tile_row_nnz(&self) -> usize {
        self.gr.1 * self.d_i() * self.gb.1
    }

    /// Non-zeros per row of the whole matrix.
    pub fn row_nnz(&self) -> usize {
        self.d_o() * self.tile_row_nnz()
    }

    /// Overall fractional sparsity `1 − (1−sp_o)(1−sp_i)`.
    pub fn sparsity(&self) -> f64 {
        1.0 - (1.0 - self.go.sp) * (1.0 - self.gi.sp)
    }

    /// Row-repetition amount `|G_r.U| · |G_b.U|` (§5 role of G_r, G_b).
    pub fn row_repetition(&self) -> usize {
        self.gr.0 * self.gb.0
    }

    /// RCUBS blocking levels `B_j = (Π_{i>j}|G_i.U|, Π_{i>j}|G_i.V|)`.
    pub fn blocking_levels(&self) -> Vec<(usize, usize)> {
        let us = [self.go.nu, self.gr.0, self.gi.nu, self.gb.0];
        let vs = [self.go.nv, self.gr.1, self.gi.nv, self.gb.1];
        (1..4)
            .map(|j| (us[j..].iter().product(), vs[j..].iter().product()))
            .collect()
    }

    /// Validate structural requirements before sampling.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, s) in [("G_o", self.go), ("G_i", self.gi)] {
            anyhow::ensure!(s.nu > 0 && s.nv > 0, "{name} has zero side");
            anyhow::ensure!((0.0..1.0).contains(&s.sp), "{name} sparsity {} out of range", s.sp);
            crate::graph::lift::lifts_for_sparsity(s.sp)
                .map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
            anyhow::ensure!(s.dl() >= 1, "{name} degree would be zero at sp={}", s.sp);
        }
        anyhow::ensure!(self.gr.0 > 0 && self.gr.1 > 0, "G_r has zero side");
        anyhow::ensure!(self.gb.0 > 0 && self.gb.1 > 0, "G_b has zero side");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("go_nu", self.go.nu)
            .set("go_nv", self.go.nv)
            .set("go_sp", self.go.sp)
            .set("gr_nu", self.gr.0)
            .set("gr_nv", self.gr.1)
            .set("gi_nu", self.gi.nu)
            .set("gi_nv", self.gi.nv)
            .set("gi_sp", self.gi.sp)
            .set("gb_nu", self.gb.0)
            .set("gb_nv", self.gb.1);
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Rbgp4Config> {
        let f = |k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing {k}"))
        };
        Ok(Rbgp4Config {
            go: GraphSpec::new(j.req_usize("go_nu")?, j.req_usize("go_nv")?, f("go_sp")?),
            gr: (j.req_usize("gr_nu")?, j.req_usize("gr_nv")?),
            gi: GraphSpec::new(j.req_usize("gi_nu")?, j.req_usize("gi_nv")?, f("gi_sp")?),
            gb: (j.req_usize("gb_nu")?, j.req_usize("gb_nv")?),
        })
    }
}

/// A sampled RBGP4 mask: the two sparse base graphs (the complete ones are
/// implicit). This is the connectivity object; weights live in
/// [`Rbgp4Matrix`].
#[derive(Clone, Debug)]
pub struct Rbgp4Mask {
    pub config: Rbgp4Config,
    pub go: BipartiteGraph,
    pub gi: BipartiteGraph,
}

impl Rbgp4Mask {
    /// Sample a mask: both sparse base graphs drawn as Ramanujan graphs via
    /// 2-lift rejection sampling (falls back to best-λ₂ expander after
    /// `attempts`, which only matters for extreme shapes).
    pub fn sample(config: Rbgp4Config, rng: &mut Rng) -> anyhow::Result<Rbgp4Mask> {
        config.validate()?;
        let (go, _) = ramanujan::generate_best_effort(config.go.nu, config.go.nv, config.go.sp, rng, 64)?;
        let (gi, _) = ramanujan::generate_best_effort(config.gi.nu, config.gi.nv, config.gi.sp, rng, 64)?;
        Ok(Rbgp4Mask {
            config,
            go: go.graph,
            gi: gi.graph,
        })
    }

    /// Build from explicit base graphs (tests / deserialization).
    pub fn from_graphs(
        config: Rbgp4Config,
        go: BipartiteGraph,
        gi: BipartiteGraph,
    ) -> anyhow::Result<Rbgp4Mask> {
        anyhow::ensure!(go.nu == config.go.nu && go.nv == config.go.nv, "G_o shape mismatch");
        anyhow::ensure!(gi.nu == config.gi.nu && gi.nv == config.gi.nv, "G_i shape mismatch");
        anyhow::ensure!(
            go.left_degree() == Some(config.d_o()),
            "G_o degree {:?} != {}",
            go.left_degree(),
            config.d_o()
        );
        anyhow::ensure!(
            gi.left_degree() == Some(config.d_i()),
            "G_i degree {:?} != {}",
            gi.left_degree(),
            config.d_i()
        );
        Ok(Rbgp4Mask { config, go, gi })
    }

    pub fn rows(&self) -> usize {
        self.config.rows()
    }

    pub fn cols(&self) -> usize {
        self.config.cols()
    }

    /// Decompose a row index into `(u_o, u_r, u_i, u_b)`.
    #[inline]
    pub fn row_coords(&self, u: usize) -> (usize, usize, usize, usize) {
        let c = &self.config;
        let ub = u % c.gb.0;
        let u = u / c.gb.0;
        let ui = u % c.gi.nu;
        let u = u / c.gi.nu;
        let ur = u % c.gr.0;
        let uo = u / c.gr.0;
        (uo, ur, ui, ub)
    }

    /// Compose a column index from `(v_o, v_r, v_i, v_b)`.
    #[inline]
    pub fn col_index(&self, vo: usize, vr: usize, vi: usize, vb: usize) -> usize {
        let c = &self.config;
        ((vo * c.gr.1 + vr) * c.gi.nv + vi) * c.gb.1 + vb
    }

    /// Sorted non-zero column indices of row `u` (ascending — see module doc).
    pub fn row_nonzero_cols(&self, u: usize) -> Vec<usize> {
        let c = &self.config;
        let (uo, _ur, ui, _ub) = self.row_coords(u);
        let mut cols = Vec::with_capacity(c.row_nnz());
        for &vo in &self.go.adj[uo] {
            for vr in 0..c.gr.1 {
                for &vi in &self.gi.adj[ui] {
                    for vb in 0..c.gb.1 {
                        cols.push(self.col_index(vo, vr, vi, vb));
                    }
                }
            }
        }
        cols
    }

    /// Dense 0/1 mask (row-major rows × cols).
    pub fn dense(&self) -> Vec<f32> {
        let (rows, cols) = (self.rows(), self.cols());
        let mut m = vec![0.0f32; rows * cols];
        for u in 0..rows {
            for v in self.row_nonzero_cols(u) {
                m[u * cols + v] = 1.0;
            }
        }
        m
    }

    /// The full product graph `G_o ⊗ G_r ⊗ G_i ⊗ G_b` (for spectral checks;
    /// expensive for big configs).
    pub fn product_graph(&self) -> BipartiteGraph {
        let gr = BipartiteGraph::complete(self.config.gr.0, self.config.gr.1);
        let gb = BipartiteGraph::complete(self.config.gb.0, self.config.gb.1);
        product_many(&[&self.go, &gr, &self.gi, &gb]).expect("non-empty")
    }

    /// Flattened `(m_o, d_o)` adjacency of `G_o` as u32 (artifact input).
    pub fn adj_o_flat(&self) -> Vec<u32> {
        self.go.adj.iter().flatten().map(|&v| v as u32).collect()
    }

    /// Flattened `(m_i, d_i)` adjacency of `G_i` as u32.
    pub fn adj_i_flat(&self) -> Vec<u32> {
        self.gi.adj.iter().flatten().map(|&v| v as u32).collect()
    }

    /// Deterministic hash of the mask *structure* (config + both base-graph
    /// adjacencies). Two masks with equal hashes describe the same sparsity
    /// pattern, so kernel execution plans built for one are valid for the
    /// other — this is the plan-cache key ingredient
    /// ([`crate::kernels::plan::PlanKey`]).
    pub fn structure_hash(&self) -> u64 {
        let c = &self.config;
        let mut h = crate::util::Fnv::new();
        h.push_all(
            [
                c.go.nu,
                c.go.nv,
                c.gr.0,
                c.gr.1,
                c.gi.nu,
                c.gi.nv,
                c.gb.0,
                c.gb.1,
            ]
            .into_iter()
            .map(|x| x as u64),
        );
        h.push(c.go.sp.to_bits());
        h.push(c.gi.sp.to_bits());
        h.push_all(self.go.adj.iter().flatten().map(|&v| v as u64));
        h.push_all(self.gi.adj.iter().flatten().map(|&v| v as u64));
        h.finish()
    }

    /// Succinct index memory in *elements* (`Σ|E(base)|`, §4 Memory
    /// efficiency). Complete graphs contribute their edge count too, per the
    /// paper's Figure-3 accounting (8+2+8+4).
    pub fn succinct_index_elems(&self) -> usize {
        self.go.num_edges()
            + self.config.gr.0 * self.config.gr.1
            + self.gi.num_edges()
            + self.config.gb.0 * self.config.gb.1
    }

    /// Generic adjacency-list index memory in elements (`|E(G)|`).
    pub fn generic_index_elems(&self) -> usize {
        self.rows() * self.config.row_nnz()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("config", self.config.to_json())
            .set("adj_o", self.adj_o_flat().iter().map(|&x| x as usize).collect::<Vec<_>>())
            .set("adj_i", self.adj_i_flat().iter().map(|&x| x as usize).collect::<Vec<_>>());
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Rbgp4Mask> {
        let config = Rbgp4Config::from_json(
            j.get("config").ok_or_else(|| anyhow::anyhow!("missing config"))?,
        )?;
        let parse_adj = |key: &str, nu: usize, d: usize| -> anyhow::Result<Vec<Vec<usize>>> {
            let flat = j.req_arr(key)?;
            anyhow::ensure!(flat.len() == nu * d, "{key} length {} != {}x{}", flat.len(), nu, d);
            Ok((0..nu)
                .map(|u| {
                    flat[u * d..(u + 1) * d]
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(usize::MAX))
                        .collect()
                })
                .collect())
        };
        let go = BipartiteGraph {
            nu: config.go.nu,
            nv: config.go.nv,
            adj: parse_adj("adj_o", config.go.nu, config.d_o())?,
        };
        let gi = BipartiteGraph {
            nu: config.gi.nu,
            nv: config.gi.nv,
            adj: parse_adj("adj_i", config.gi.nu, config.d_i())?,
        };
        Rbgp4Mask::from_graphs(config, go, gi)
    }
}

/// RBGP4 weight matrix in compact storage: `data[(u, k)]` is the weight of
/// the `k`-th non-zero of row `u` (ascending column order).
#[derive(Clone, Debug)]
pub struct Rbgp4Matrix {
    pub mask: Rbgp4Mask,
    /// `(rows, row_nnz)` row-major.
    pub data: Vec<f32>,
}

impl Rbgp4Matrix {
    /// Random weights (He-style scale 1/√fan_in over *non-zero* fan-in, the
    /// right init for predefined-sparsity training).
    pub fn random(mask: Rbgp4Mask, rng: &mut Rng) -> Rbgp4Matrix {
        let n = mask.rows() * mask.config.row_nnz();
        let scale = (2.0 / mask.config.row_nnz() as f64).sqrt() as f32;
        let data = rng.normal_vec_f32(n, scale);
        Rbgp4Matrix { mask, data }
    }

    /// Gather compact storage from a dense matrix (entries off the mask are
    /// ignored).
    pub fn from_dense(mask: Rbgp4Mask, dense: &[f32]) -> anyhow::Result<Rbgp4Matrix> {
        let (rows, cols) = (mask.rows(), mask.cols());
        anyhow::ensure!(dense.len() == rows * cols, "dense shape mismatch");
        let rn = mask.config.row_nnz();
        let mut data = vec![0.0f32; rows * rn];
        for u in 0..rows {
            for (k, v) in mask.row_nonzero_cols(u).into_iter().enumerate() {
                data[u * rn + k] = dense[u * cols + v];
            }
        }
        Ok(Rbgp4Matrix { mask, data })
    }

    /// Scatter back to a dense rows × cols matrix.
    pub fn to_dense(&self) -> Vec<f32> {
        let (rows, cols) = (self.mask.rows(), self.mask.cols());
        let rn = self.mask.config.row_nnz();
        let mut dense = vec![0.0f32; rows * cols];
        for u in 0..rows {
            for (k, v) in self.mask.row_nonzero_cols(u).into_iter().enumerate() {
                dense[u * cols + v] = self.data[u * rn + k];
            }
        }
        dense
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::pattern;

    fn small_config() -> Rbgp4Config {
        Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        }
    }

    #[test]
    fn config_arithmetic() {
        let c = small_config();
        assert_eq!(c.rows(), 4 * 2 * 4 * 2);
        assert_eq!(c.cols(), 4 * 1 * 4 * 2);
        assert_eq!(c.tile_m(), 16);
        assert_eq!(c.tile_k(), 8);
        assert_eq!(c.d_o(), 2);
        assert_eq!(c.d_i(), 2);
        assert_eq!(c.tile_row_nnz(), 1 * 2 * 2);
        assert_eq!(c.row_nnz(), 8);
        assert!((c.sparsity() - 0.75).abs() < 1e-12);
        assert_eq!(c.row_repetition(), 4);
        assert_eq!(c.blocking_levels(), vec![(16, 8), (8, 8), (2, 2)]);
    }

    #[test]
    fn paper_default_shape() {
        let c = Rbgp4Config::paper_default(0.5, 0.5);
        assert_eq!(c.rows(), 32 * 4 * 32);
        assert_eq!(c.cols(), 128 * 32);
        assert_eq!(c.tile_m(), 128);
        assert_eq!(c.tile_k(), 32);
        assert!((c.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn mask_sparsity_matches_config() {
        let mut rng = Rng::new(77);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let dense = mask.dense();
        let nnz = dense.iter().filter(|&&x| x != 0.0).count();
        let total = mask.rows() * mask.cols();
        assert_eq!(nnz, mask.rows() * mask.config.row_nnz());
        assert!((1.0 - nnz as f64 / total as f64 - mask.config.sparsity()).abs() < 1e-12);
    }

    #[test]
    fn mask_dense_matches_product_graph() {
        let mut rng = Rng::new(78);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        assert_eq!(mask.dense(), mask.product_graph().biadjacency());
    }

    #[test]
    fn mask_is_rcubs_at_config_levels() {
        let mut rng = Rng::new(79);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let dense = mask.dense();
        let levels = mask.config.blocking_levels();
        assert!(pattern::is_rcubs(&dense, mask.rows(), mask.cols(), &levels).unwrap());
    }

    #[test]
    fn row_repetition_matches_config() {
        let mut rng = Rng::new(80);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let dense = mask.dense();
        let group_of = pattern::row_repetition_groups(&dense, mask.rows(), mask.cols());
        let groups = group_of.iter().copied().max().unwrap() + 1;
        // Rows with equal (adj_o[u_o], adj_i[u_i]) repeat; there are at most
        // m_o·m_i distinct patterns (fewer if base vertices coincide), and
        // every pattern class size is a multiple of m_r·m_b = row_repetition.
        assert!(groups <= mask.rows() / mask.config.row_repetition());
        let mut sizes = vec![0usize; groups];
        for &g in &group_of {
            sizes[g] += 1;
        }
        for s in sizes {
            assert_eq!(s % mask.config.row_repetition(), 0);
        }
    }

    #[test]
    fn row_nonzero_cols_sorted_and_on_mask() {
        let mut rng = Rng::new(81);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let dense = mask.dense();
        for u in 0..mask.rows() {
            let cols = mask.row_nonzero_cols(u);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {u} not sorted");
            for &v in &cols {
                assert_eq!(dense[u * mask.cols() + v], 1.0);
            }
            assert_eq!(cols.len(), mask.config.row_nnz());
        }
    }

    #[test]
    fn compact_roundtrip() {
        let mut rng = Rng::new(82);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let w = Rbgp4Matrix::random(mask, &mut rng);
        let dense = w.to_dense();
        let back = Rbgp4Matrix::from_dense(w.mask.clone(), &dense).unwrap();
        assert_eq!(w.data, back.data);
    }

    #[test]
    fn succinct_memory_figure3_ratio() {
        // Paper Figure 3: 512 edges vs 22 stored base-graph edges ≈ 23x.
        // With our accounting on the small config: |E| = rows·row_nnz.
        let mut rng = Rng::new(83);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let succinct = mask.succinct_index_elems();
        let generic = mask.generic_index_elems();
        assert_eq!(succinct, 8 + 2 + 8 + 4);
        assert_eq!(generic, 64 * 8);
        assert!(generic / succinct > 20);
    }

    #[test]
    fn structure_hash_tracks_pattern_not_values() {
        let mut rng = Rng::new(86);
        let a = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let b = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        assert_eq!(a.structure_hash(), a.clone().structure_hash());
        // Independent samples of the same config almost surely differ.
        assert_ne!(a.structure_hash(), b.structure_hash());
        // Weights don't enter the hash: two matrices on one mask share it.
        let w1 = Rbgp4Matrix::random(a.clone(), &mut rng);
        let w2 = Rbgp4Matrix::random(a.clone(), &mut rng);
        assert_eq!(w1.mask.structure_hash(), w2.mask.structure_hash());
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = Rng::new(84);
        let mask = Rbgp4Mask::sample(small_config(), &mut rng).unwrap();
        let j = mask.to_json();
        let back = Rbgp4Mask::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.config, mask.config);
        assert_eq!(back.go, mask.go);
        assert_eq!(back.gi, mask.gi);
    }

    #[test]
    fn validate_rejects_bad_sparsity() {
        let mut c = small_config();
        c.go.sp = 0.6;
        assert!(c.validate().is_err());
        c.go.sp = 0.5;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn dense_config_has_no_zeroes() {
        let c = Rbgp4Config {
            go: GraphSpec::new(2, 2, 0.0),
            gr: (2, 2),
            gi: GraphSpec::new(2, 2, 0.0),
            gb: (1, 1),
        };
        let mut rng = Rng::new(85);
        let mask = Rbgp4Mask::sample(c, &mut rng).unwrap();
        assert!(mask.dense().iter().all(|&x| x == 1.0));
    }
}
