//! Sparse-matrix substrate: the §3 pattern taxonomy with validators, the
//! RBGP4 contract format (compact storage + succinct index), the CSR/BSR
//! baseline formats, and the Table-1 memory accounting.

pub mod bsr;
pub mod csr;
pub mod memory;
pub mod pattern;
pub mod rbgp4;

pub use bsr::BsrMatrix;
pub use csr::CsrMatrix;
pub use memory::Pattern;
pub use rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
