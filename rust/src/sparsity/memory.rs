//! Memory-footprint accounting (Table 1 "Mem" column).
//!
//! The paper stores a sparse layer as parameters (4 bytes each) plus
//! connectivity. The connectivity cost is what separates the patterns:
//!
//! * dense          — no index:              `4·P`
//! * unstructured   — adjacency list (§4):   `4·nnz + 4·nnz  = 8·nnz`
//!   (this is why Table 1's 50 %-unstructured equals the dense footprint)
//! * block (bh,bw)  — one index per block:   `4·nnz + 4·nnz/(bh·bw)`
//! * RBGP4          — base-graph adjacency:  `4·nnz + 4·Σ|E(base_i)|`
//!   (the succinct representation; the index term is negligible)

use crate::sparsity::rbgp4::Rbgp4Config;

/// Sparsity pattern kinds compared throughout the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    Dense,
    Unstructured,
    /// Block with size (bh, bw); the paper benchmarks (4, 4).
    Block(usize, usize),
    Rbgp4,
}

impl Pattern {
    pub fn name(&self) -> &'static str {
        match self {
            Pattern::Dense => "Dense",
            Pattern::Unstructured => "Unstructured",
            Pattern::Block(_, _) => "Block",
            Pattern::Rbgp4 => "RBGP4",
        }
    }
}

/// Memory in bytes for one weight matrix of `params` total elements at
/// fractional sparsity `sp` (fraction of *removed* elements) under `pattern`.
///
/// `rbgp4_index_elems` supplies the succinct index size when known (pass 0
/// to ignore the sub-0.1 % term — the paper's numbers are insensitive to it).
pub fn layer_bytes(params: usize, sp: f64, pattern: Pattern, rbgp4_index_elems: usize) -> u64 {
    let nnz = ((params as f64) * (1.0 - sp)).round() as u64;
    match pattern {
        Pattern::Dense => 4 * params as u64,
        Pattern::Unstructured => 8 * nnz,
        Pattern::Block(bh, bw) => 4 * nnz + 4 * nnz / (bh * bw) as u64,
        Pattern::Rbgp4 => 4 * nnz + 4 * rbgp4_index_elems as u64,
    }
}

/// Succinct index elements for an RBGP4 config (Σ|E(base)| incl. complete
/// graphs, matching the paper's Figure-3 count).
pub fn rbgp4_index_elems(c: &Rbgp4Config) -> usize {
    c.go.nu * c.go.dl() + c.gr.0 * c.gr.1 + c.gi.nu * c.gi.dl() + c.gb.0 * c.gb.1
}

/// Memory for a whole network: `layers` gives (params, is_sparsified) per
/// layer — the paper keeps the first (input) conv and the classifier dense.
pub fn network_bytes(layers: &[(usize, bool)], sp: f64, pattern: Pattern) -> u64 {
    layers
        .iter()
        .map(|&(params, sparsified)| {
            if sparsified && pattern != Pattern::Dense {
                // Index term for RBGP4 is per-layer-config dependent but
                // bounded by ~0.1% of nnz; use 0 here (documented in module
                // docs) — per-config exact values are available via
                // `rbgp4_index_elems` when a concrete config exists.
                layer_bytes(params, sp, pattern, 0)
            } else {
                layer_bytes(params, 0.0, Pattern::Dense, 0)
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::rbgp4::GraphSpec;

    #[test]
    fn unstructured_at_half_equals_dense() {
        // The paper's Table 1 quirk: 50% unstructured == dense memory.
        let p = 1_000_000;
        assert_eq!(
            layer_bytes(p, 0.5, Pattern::Unstructured, 0),
            layer_bytes(p, 0.0, Pattern::Dense, 0)
        );
    }

    #[test]
    fn block_beats_unstructured_by_near_2x() {
        let p = 1_000_000;
        let u = layer_bytes(p, 0.75, Pattern::Unstructured, 0) as f64;
        let b = layer_bytes(p, 0.75, Pattern::Block(4, 4), 0) as f64;
        let ratio = u / b;
        assert!(ratio > 1.8 && ratio < 2.0, "ratio={ratio}");
    }

    #[test]
    fn rbgp4_at_most_block() {
        let p = 1_000_000;
        for &sp in &[0.5, 0.75, 0.875, 0.9375] {
            let b = layer_bytes(p, sp, Pattern::Block(4, 4), 0);
            let r = layer_bytes(p, sp, Pattern::Rbgp4, 100);
            assert!(r < b, "sp={sp}: rbgp4 {r} !< block {b}");
        }
    }

    #[test]
    fn rbgp4_index_is_tiny() {
        let c = Rbgp4Config {
            go: GraphSpec::new(32, 128, 0.5),
            gr: (4, 1),
            gi: GraphSpec::new(32, 32, 0.5),
            gb: (1, 1),
        };
        let idx = rbgp4_index_elems(&c);
        let nnz = (c.rows() * c.cols()) as f64 * (1.0 - c.sparsity());
        assert!((idx as f64) < 0.01 * nnz, "idx={idx} nnz={nnz}");
    }

    #[test]
    fn network_keeps_dense_layers_dense() {
        let layers = [(1000, false), (10_000, true)];
        let m = network_bytes(&layers, 0.75, Pattern::Unstructured);
        assert_eq!(m, 4 * 1000 + 8 * 2500);
        let d = network_bytes(&layers, 0.75, Pattern::Dense);
        assert_eq!(d, 4 * 11_000);
    }
}
