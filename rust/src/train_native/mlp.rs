//! Masked two-layer MLP with hand-written backprop (pure Rust).
//!
//! Architecture: `x (D×B) → W1∘M (H×D) → ReLU → W2 (C×H) → softmax CE`.
//! The hidden weight carries a fixed 0/1 mask `M` (predefined sparsity, as
//! in the paper's §6 setup); gradients are masked so pruned weights stay
//! exactly zero. Optimizer: SGD + momentum 0.9 + weight decay 1e-4.

use crate::data::synth::CifarLike;
use crate::kernels::autotune::TuneMode;
use crate::kernels::dense::{gemm_blocked, gemm_nt, gemm_tn};
use crate::util::rng::Rng;

// The GEMM helpers this trainer needs are the shared `kernels::dense` entry
// points (one implementation serves the trainer, the plan layer and the
// benches); `transpose` is re-exported for historical callers.
pub use crate::kernels::dense::transpose;

/// Training hyper-parameters for the native trainer.
#[derive(Clone, Debug)]
pub struct NativeTrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Autotune mode used when deriving serving models/plans from a
    /// training run (does not affect the training math itself).
    pub tune: TuneMode,
    /// Persistent tuning-cache file attached to the trainer's plan cache:
    /// schedule searches warm-start from it and record their winners there,
    /// so a second run (or the serving process pointed at the same file)
    /// builds its plans with zero measurement reps. `None` keeps tuning
    /// in-process only.
    pub tune_cache: Option<std::path::PathBuf>,
}

impl Default for NativeTrainConfig {
    fn default() -> Self {
        NativeTrainConfig {
            steps: 200,
            batch: 64,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            seed: 0,
            tune: TuneMode::default(),
            tune_cache: None,
        }
    }
}

/// The model + optimizer state.
pub struct MaskedMlp {
    pub d: usize,
    pub h: usize,
    pub c: usize,
    /// Hidden-layer mask (H × D), 0/1.
    pub mask: Vec<f32>,
    pub(crate) w1: Vec<f32>, // (H, D)
    pub(crate) b1: Vec<f32>,
    pub(crate) w2: Vec<f32>, // (C, H)
    pub(crate) b2: Vec<f32>,
    v_w1: Vec<f32>,
    v_b1: Vec<f32>,
    v_w2: Vec<f32>,
    v_b2: Vec<f32>,
}

impl MaskedMlp {
    /// He-init scaled by the *unmasked* fan-in of each row (matching the
    /// compact-storage init the AOT model uses).
    pub fn new(d: usize, h: usize, c: usize, mask: Vec<f32>, rng: &mut Rng) -> MaskedMlp {
        assert_eq!(mask.len(), h * d);
        let mut w1 = vec![0.0f32; h * d];
        for r in 0..h {
            let fan_in = mask[r * d..(r + 1) * d].iter().filter(|&&m| m != 0.0).count().max(1);
            let scale = (2.0 / fan_in as f64).sqrt() as f32;
            for col in 0..d {
                w1[r * d + col] = rng.normal_f32() * scale * mask[r * d + col];
            }
        }
        let w2scale = (1.0 / h as f64).sqrt() as f32;
        let w2 = rng.normal_vec_f32(c * h, w2scale);
        MaskedMlp {
            d,
            h,
            c,
            mask,
            w1,
            b1: vec![0.0; h],
            w2,
            b2: vec![0.0; c],
            v_w1: vec![0.0; h * d],
            v_b1: vec![0.0; h],
            v_w2: vec![0.0; c * h],
            v_b2: vec![0.0; c],
        }
    }

    /// Replace the mask with a (sub)mask, zeroing weights and momenta that
    /// fall off it — the gradual-induction primitive. Panics (debug) if the
    /// new mask is not a subset of the current one.
    pub fn tighten_mask(&mut self, new_mask: Vec<f32>) {
        assert_eq!(new_mask.len(), self.mask.len());
        debug_assert!(
            new_mask
                .iter()
                .zip(&self.mask)
                .all(|(&n, &o)| n == 0.0 || o != 0.0),
            "tighten_mask: new mask is not nested in the old one"
        );
        for i in 0..new_mask.len() {
            if new_mask[i] == 0.0 {
                self.w1[i] = 0.0;
                self.v_w1[i] = 0.0;
            }
        }
        self.mask = new_mask;
    }

    /// Replace mask and parameters wholesale (checkpoint restore),
    /// resetting momenta. Unlike [`MaskedMlp::tighten_mask`] the new mask
    /// need not nest in the old one; off-mask weights are forced to zero
    /// so the `w1 = w1 ⊙ mask` invariant survives arbitrary checkpoint
    /// data.
    pub fn load_params(
        &mut self,
        mask: Vec<f32>,
        mut w1: Vec<f32>,
        b1: Vec<f32>,
        w2: Vec<f32>,
        b2: Vec<f32>,
    ) {
        assert_eq!(mask.len(), self.h * self.d, "mask shape mismatch");
        assert_eq!(w1.len(), self.h * self.d, "w1 shape mismatch");
        assert_eq!(b1.len(), self.h, "b1 shape mismatch");
        assert_eq!(w2.len(), self.c * self.h, "w2 shape mismatch");
        assert_eq!(b2.len(), self.c, "b2 shape mismatch");
        for (w, &m) in w1.iter_mut().zip(&mask) {
            if m == 0.0 {
                *w = 0.0;
            }
        }
        self.mask = mask;
        self.w1 = w1;
        self.b1 = b1;
        self.w2 = w2;
        self.b2 = b2;
        self.v_w1.fill(0.0);
        self.v_b1.fill(0.0);
        self.v_w2.fill(0.0);
        self.v_b2.fill(0.0);
    }

    /// All parameters flattened in a fixed order (`w1, b1, w2, b2`) — the
    /// bit-identity witness for determinism regression tests: two runs with
    /// one seed must agree on every one of these f32s exactly.
    pub fn flat_params(&self) -> Vec<f32> {
        let mut p = Vec::with_capacity(
            self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len(),
        );
        p.extend_from_slice(&self.w1);
        p.extend_from_slice(&self.b1);
        p.extend_from_slice(&self.w2);
        p.extend_from_slice(&self.b2);
        p
    }

    /// Fractional sparsity of the current mask.
    pub fn mask_sparsity(&self) -> f64 {
        1.0 - self.mask.iter().filter(|&&m| m != 0.0).count() as f64 / self.mask.len() as f64
    }

    /// Forward: returns (hidden (H×B), probs (C×B)). `x` is (D×B).
    fn forward(&self, x: &[f32], b: usize) -> (Vec<f32>, Vec<f32>) {
        let mut hid = vec![0.0f32; self.h * b];
        gemm_blocked(&self.w1, x, &mut hid, self.h, self.d, b);
        for r in 0..self.h {
            for j in 0..b {
                let v = hid[r * b + j] + self.b1[r];
                hid[r * b + j] = v.max(0.0);
            }
        }
        let mut logits = vec![0.0f32; self.c * b];
        gemm_blocked(&self.w2, &hid, &mut logits, self.c, self.h, b);
        // softmax per column
        for j in 0..b {
            let mut mx = f32::NEG_INFINITY;
            for r in 0..self.c {
                logits[r * b + j] += self.b2[r];
                mx = mx.max(logits[r * b + j]);
            }
            let mut z = 0.0f32;
            for r in 0..self.c {
                let e = (logits[r * b + j] - mx).exp();
                logits[r * b + j] = e;
                z += e;
            }
            for r in 0..self.c {
                logits[r * b + j] /= z;
            }
        }
        (hid, logits)
    }

    /// One SGD step on a batch; returns the mean CE loss.
    pub fn train_step(&mut self, x: &[f32], y: &[f32], b: usize, cfg: &NativeTrainConfig) -> f32 {
        let (hid, probs) = self.forward(x, b);
        // Loss + dlogits = (probs - y)/B    (both C×B)
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; self.c * b];
        for j in 0..b {
            for r in 0..self.c {
                let p = probs[r * b + j];
                let t = y[r * b + j];
                if t > 0.0 {
                    loss -= (p.max(1e-12)).ln() * t;
                }
                dlogits[r * b + j] = (p - t) / b as f32;
            }
        }
        loss /= b as f32;

        // dW2 = dlogits · hidᵀ ; db2 = Σ dlogits
        let mut dw2 = vec![0.0f32; self.c * self.h];
        gemm_nt(&dlogits, &hid, &mut dw2, self.c, b, self.h);
        let mut db2 = vec![0.0f32; self.c];
        for r in 0..self.c {
            db2[r] = dlogits[r * b..(r + 1) * b].iter().sum();
        }
        // dhid = W2ᵀ · dlogits, gated by ReLU
        let mut dhid = vec![0.0f32; self.h * b];
        gemm_tn(&self.w2, &dlogits, &mut dhid, self.c, self.h, b);
        for idx in 0..self.h * b {
            if hid[idx] <= 0.0 {
                dhid[idx] = 0.0;
            }
        }
        // dW1 = dhid · xᵀ (masked); db1 = Σ dhid
        let mut dw1 = vec![0.0f32; self.h * self.d];
        gemm_nt(&dhid, x, &mut dw1, self.h, b, self.d);
        let mut db1 = vec![0.0f32; self.h];
        for r in 0..self.h {
            db1[r] = dhid[r * b..(r + 1) * b].iter().sum();
        }

        // SGD momentum + weight decay; W1 gradient masked.
        let upd = |p: &mut [f32], v: &mut [f32], g: &[f32], mask: Option<&[f32]>, cfg: &NativeTrainConfig| {
            for i in 0..p.len() {
                let m = mask.map(|m| m[i]).unwrap_or(1.0);
                if m == 0.0 {
                    continue;
                }
                let grad = g[i] + cfg.weight_decay * p[i];
                v[i] = cfg.momentum * v[i] + grad;
                p[i] -= cfg.lr * v[i];
            }
        };
        upd(&mut self.w1, &mut self.v_w1, &dw1, Some(&self.mask), cfg);
        upd(&mut self.b1, &mut self.v_b1, &db1, None, cfg);
        upd(&mut self.w2, &mut self.v_w2, &dw2, None, cfg);
        upd(&mut self.b2, &mut self.v_b2, &db2, None, cfg);
        loss
    }

    /// Accuracy over a (D×B) batch with integer labels.
    pub fn accuracy(&self, x: &[f32], labels: &[usize], b: usize) -> f64 {
        let (_, probs) = self.forward(x, b);
        let mut correct = 0usize;
        for (j, &lbl) in labels.iter().enumerate() {
            let mut best = (0usize, f32::NEG_INFINITY);
            for r in 0..self.c {
                if probs[r * b + j] > best.1 {
                    best = (r, probs[r * b + j]);
                }
            }
            correct += (best.0 == lbl) as usize;
        }
        correct as f64 / b as f64
    }

    /// Train on `data` per `cfg`; returns (final loss, held-out accuracy).
    pub fn train(&mut self, data: &mut CifarLike, cfg: &NativeTrainConfig) -> (f32, f64) {
        let mut loss = f32::NAN;
        for _ in 0..cfg.steps {
            let batch = data.train_batch(cfg.batch);
            let xt = transpose(&batch.x, cfg.batch, self.d);
            let yt = transpose(&batch.y, cfg.batch, self.c);
            loss = self.train_step(&xt, &yt, cfg.batch, cfg);
        }
        let mut acc = 0.0;
        let evals = 8;
        for _ in 0..evals {
            let tb = data.test_batch(cfg.batch);
            let xt = transpose(&tb.x, cfg.batch, self.d);
            acc += self.accuracy(&xt, &tb.labels, cfg.batch);
        }
        (loss, acc / evals as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::memory::Pattern;
    use crate::train_native::masks::pattern_mask;

    #[test]
    fn masked_weights_stay_zero() {
        let mut rng = Rng::new(31);
        let mask = pattern_mask(Pattern::Unstructured, 32, 16, 0.75, &mut rng).unwrap();
        let mut mlp = MaskedMlp::new(16, 32, 4, mask.clone(), &mut rng);
        let cfg = NativeTrainConfig {
            steps: 5,
            batch: 8,
            ..NativeTrainConfig::default()
        };
        let mut data = CifarLike::new(16, 4, 3);
        mlp.train(&mut data, &cfg);
        for (w, m) in mlp.w1.iter().zip(&mask) {
            if *m == 0.0 {
                assert_eq!(*w, 0.0);
            }
        }
    }

    #[test]
    fn native_training_learns_the_task() {
        let mut rng = Rng::new(32);
        let mask = pattern_mask(Pattern::Rbgp4, 128, 128, 0.75, &mut rng).unwrap();
        let mut mlp = MaskedMlp::new(128, 128, 4, mask, &mut rng);
        let cfg = NativeTrainConfig {
            steps: 120,
            batch: 32,
            lr: 0.05,
            ..NativeTrainConfig::default()
        };
        let mut data = CifarLike::new(128, 4, 5);
        let (loss, acc) = mlp.train(&mut data, &cfg);
        assert!(loss < 0.8, "loss {loss}");
        assert!(acc > 0.8, "acc {acc}");
    }
}
