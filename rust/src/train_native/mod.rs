//! Native (pure-Rust) masked training — the accuracy-parity substrate.
//!
//! Table 1's accuracy claim is that at equal sparsity, RBGP4 masks match
//! unstructured and block masks. The AOT path trains only the RBGP4 model
//! (its mask is baked into the artifact), so this module provides a small
//! self-contained trainer where the mask is a runtime input: a two-layer
//! MLP with hand-written forward/backward over *masked dense* weights,
//! trained with the paper's SGD-momentum recipe. `examples/accuracy_parity.rs`
//! sweeps all four patterns at the paper's sparsities.

pub mod gradual;
pub mod masks;
pub mod mlp;

pub use gradual::{
    is_nested, mask_nnz, nested_masks, nested_masks_from, train_gradual, GradualSchedule,
};
pub use masks::pattern_mask;
pub use mlp::{MaskedMlp, NativeTrainConfig};
