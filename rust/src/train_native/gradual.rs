//! Gradual RBGP4 structure induction — the paper's §7 future-work item:
//! *"generating combinatorial structured sparsity patterns like RBGP4
//! during the training process could lead to more accurate models as
//! structure is induced in a gradual manner."*
//!
//! Implementation: the *final* RBGP4 mask is sampled up front; intermediate
//! masks are nested supersets of it (each row of each sparse base graph
//! keeps its final edges and carries extra random edges that are removed at
//! the next milestone). Training starts dense and tightens the mask on a
//! step schedule; because every mask contains the next one, weights are
//! only ever zeroed, never revived — the structure *emerges* rather than
//! being imposed at initialization.

use crate::sparsity::rbgp4::{Rbgp4Config, Rbgp4Mask};
use crate::train_native::mlp::MaskedMlp;
use crate::util::rng::Rng;

/// One milestone: at `at_frac`·steps, tighten to `mask_index`.
#[derive(Clone, Debug)]
pub struct GradualSchedule {
    /// Fractions of total steps at which the mask tightens; the mask chain
    /// is dense → supersets → final, one entry per fraction.
    pub fractions: Vec<f64>,
}

impl Default for GradualSchedule {
    fn default() -> Self {
        // Dense for the first quarter, half-tight until 60 %, final after.
        GradualSchedule {
            fractions: vec![0.25, 0.6],
        }
    }
}

impl GradualSchedule {
    /// A validated schedule: fractions strictly increasing, each in (0, 1).
    pub fn from_fractions(fractions: Vec<f64>) -> anyhow::Result<GradualSchedule> {
        anyhow::ensure!(
            !fractions.is_empty(),
            "gradual schedule needs at least one milestone fraction"
        );
        for &f in &fractions {
            anyhow::ensure!(
                f > 0.0 && f < 1.0,
                "milestone fraction {f} out of (0, 1)"
            );
        }
        anyhow::ensure!(
            fractions.windows(2).all(|w| w[0] < w[1]),
            "milestone fractions must be strictly increasing: {fractions:?}"
        );
        Ok(GradualSchedule { fractions })
    }

    /// Parse a CLI-style `"0.25,0.6"` list.
    pub fn parse(text: &str) -> anyhow::Result<GradualSchedule> {
        let fractions = text
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad milestone fraction '{s}'"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        GradualSchedule::from_fractions(fractions)
    }

    /// Number of mask-tightening milestones this schedule fires.
    pub fn milestones(&self) -> usize {
        self.fractions.len()
    }
}

/// Build the nested mask chain for `config`: returns masks of increasing
/// sparsity, ending at the exact RBGP4 mask; every mask is a superset of
/// its successor.
///
/// Intermediate masks relax the two sparse base graphs: each left vertex
/// keeps its final adjacency plus `extra` random additional neighbours.
/// (Intermediates are row-regular but not exactly biregular — they exist
/// only during training; the *final* structure is a true RBGP4 mask.)
pub fn nested_masks(
    config: Rbgp4Config,
    levels: usize,
    rng: &mut Rng,
) -> anyhow::Result<Vec<Vec<f32>>> {
    let final_mask = Rbgp4Mask::sample(config, rng)?;
    Ok(nested_masks_from(&final_mask, levels, rng))
}

/// [`nested_masks`] from an already-sampled final mask — the trainer's
/// entry point: it keeps the [`Rbgp4Mask`] (for structure hashes and final
/// exactness checks) and derives the chain from it, so the mask is sampled
/// once per run.
pub fn nested_masks_from(final_mask: &Rbgp4Mask, levels: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    let config = final_mask.config;
    let (rows, cols) = (final_mask.rows(), final_mask.cols());
    let final_dense = final_mask.dense();
    let mut chain = Vec::with_capacity(levels + 1);
    // Interpolate the number of *extra* non-zeros per row from full density
    // down to zero across the chain. One shuffled extra-column order per
    // row, shared by all levels (each level takes a shrinking prefix), so
    // the chain is nested by construction.
    let full_extra = cols - config.row_nnz();
    let extra_order: Vec<Vec<usize>> = (0..rows)
        .map(|u| {
            let row = &final_dense[u * cols..(u + 1) * cols];
            let mut off: Vec<usize> = (0..cols).filter(|&c| row[c] == 0.0).collect();
            rng.shuffle(&mut off);
            off
        })
        .collect();
    // Per-level extra counts, enforced *strictly* decreasing toward the
    // final mask wherever capacity allows (when `full_extra >= levels`
    // every level is a strict superset of its successor; tighter shapes
    // saturate at full density and may repeat the densest level).
    let mut extras = vec![0usize; levels];
    let mut prev = 0usize; // the final mask carries zero extras
    for level in (0..levels).rev() {
        let frac = 1.0 - (level as f64 + 1.0) / (levels as f64 + 1.0);
        let mut e = ((full_extra as f64) * frac).round() as usize;
        if e <= prev {
            e = prev + 1;
        }
        extras[level] = e.min(full_extra);
        prev = extras[level];
    }
    for &extra in &extras {
        let mut mask = final_dense.clone();
        for u in 0..rows {
            let row = &mut mask[u * cols..(u + 1) * cols];
            for &c in extra_order[u].iter().take(extra) {
                row[c] = 1.0;
            }
        }
        chain.push(mask);
    }
    chain.push(final_dense);
    chain
}

/// Non-zero count of a dense 0/1 mask.
pub fn mask_nnz(mask: &[f32]) -> usize {
    mask.iter().filter(|&&v| v != 0.0).count()
}

/// Verify the nesting invariant: every mask is a superset of the next.
pub fn is_nested(chain: &[Vec<f32>]) -> bool {
    chain.windows(2).all(|w| {
        w[0].iter()
            .zip(&w[1])
            .all(|(&outer, &inner)| inner == 0.0 || outer != 0.0)
    })
}

/// Train `mlp`-style model with gradual tightening toward `config`'s mask.
/// Returns (final loss, held-out accuracy). The model starts fully dense;
/// at each schedule fraction the next mask in the chain is applied.
pub fn train_gradual(
    d: usize,
    h: usize,
    c: usize,
    config: Rbgp4Config,
    schedule: &GradualSchedule,
    train_cfg: &crate::train_native::mlp::NativeTrainConfig,
    data: &mut crate::data::synth::CifarLike,
    rng: &mut Rng,
) -> anyhow::Result<(f32, f64)> {
    anyhow::ensure!(config.rows() == h && config.cols() == d, "config/shape mismatch");
    let chain = nested_masks(config, schedule.fractions.len(), rng)?;
    debug_assert!(is_nested(&chain));
    let dense_mask = vec![1.0f32; h * d];
    let mut mlp = MaskedMlp::new(d, h, c, dense_mask, rng);

    let mut next_mask = 0usize;
    let mut loss = f32::NAN;
    for step in 0..train_cfg.steps {
        let frac = step as f64 / train_cfg.steps as f64;
        while next_mask < schedule.fractions.len() && frac >= schedule.fractions[next_mask] {
            mlp.tighten_mask(chain[next_mask].clone());
            next_mask += 1;
        }
        // Final tightening near the end if the schedule didn't reach it.
        if next_mask == schedule.fractions.len() && frac >= *schedule.fractions.last().unwrap_or(&0.0)
        {
            mlp.tighten_mask(chain.last().unwrap().clone());
            next_mask += 1;
        }
        let batch = data.train_batch(train_cfg.batch);
        let xt = crate::train_native::mlp::transpose(&batch.x, train_cfg.batch, d);
        let yt = crate::train_native::mlp::transpose(&batch.y, train_cfg.batch, c);
        loss = mlp.train_step(&xt, &yt, train_cfg.batch, train_cfg);
    }
    // Ensure the final structure is in place even for degenerate schedules.
    mlp.tighten_mask(chain.last().unwrap().clone());

    let mut acc = 0.0;
    let evals = 8;
    for _ in 0..evals {
        let tb = data.test_batch(train_cfg.batch);
        let xt = crate::train_native::mlp::transpose(&tb.x, train_cfg.batch, d);
        acc += mlp.accuracy(&xt, &tb.labels, train_cfg.batch);
    }
    Ok((loss, acc / evals as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::rbgp4::GraphSpec;

    fn cfg() -> Rbgp4Config {
        Rbgp4Config {
            go: GraphSpec::new(4, 16, 0.5),
            gr: (4, 1),
            gi: GraphSpec::new(8, 8, 0.5),
            gb: (1, 1),
        }
    }

    #[test]
    fn chain_is_nested_and_ends_at_final_sparsity() {
        let mut rng = Rng::new(41);
        let chain = nested_masks(cfg(), 2, &mut rng).unwrap();
        assert_eq!(chain.len(), 3);
        assert!(is_nested(&chain));
        let sp = |m: &Vec<f32>| 1.0 - m.iter().filter(|&&v| v != 0.0).count() as f64 / m.len() as f64;
        // Strictly increasing sparsity along the chain.
        assert!(sp(&chain[0]) < sp(&chain[1]));
        assert!(sp(&chain[1]) < sp(&chain[2]));
        assert!((sp(&chain[2]) - cfg().sparsity()).abs() < 1e-9);
    }

    #[test]
    fn schedule_parse_and_validation() {
        let s = GradualSchedule::parse("0.25, 0.6").unwrap();
        assert_eq!(s.fractions, vec![0.25, 0.6]);
        assert_eq!(s.milestones(), 2);
        assert!(GradualSchedule::parse("").is_err());
        assert!(GradualSchedule::parse("0.6,0.25").is_err(), "must increase");
        assert!(GradualSchedule::parse("0.0,0.5").is_err(), "open interval");
        assert!(GradualSchedule::parse("0.5,1.0").is_err(), "open interval");
        assert!(GradualSchedule::parse("0.5,x").is_err());
        assert!(GradualSchedule::from_fractions(vec![0.3]).is_ok());
    }

    #[test]
    fn chain_is_strictly_nested_with_ample_capacity() {
        // cols - row_nnz is large here, so every level must be a *strict*
        // superset of its successor (strictly decreasing nnz).
        let mut rng = Rng::new(43);
        let final_mask = Rbgp4Mask::sample(cfg(), &mut rng).unwrap();
        let chain = nested_masks_from(&final_mask, 3, &mut rng);
        assert_eq!(chain.len(), 4);
        assert!(is_nested(&chain));
        for w in chain.windows(2) {
            assert!(
                mask_nnz(&w[0]) > mask_nnz(&w[1]),
                "levels must strictly tighten: {} vs {}",
                mask_nnz(&w[0]),
                mask_nnz(&w[1])
            );
        }
        assert_eq!(chain.last().unwrap(), &final_mask.dense());
    }

    #[test]
    fn gradual_training_reaches_final_structure_and_learns() {
        let mut rng = Rng::new(42);
        let config = cfg();
        let (d, h, c) = (128usize, 128usize, 4usize);
        let mut data = crate::data::synth::CifarLike::new(d, c, 11);
        let tc = crate::train_native::mlp::NativeTrainConfig {
            steps: 120,
            batch: 32,
            lr: 0.05,
            ..Default::default()
        };
        let (loss, acc) =
            train_gradual(d, h, c, config, &GradualSchedule::default(), &tc, &mut data, &mut rng)
                .unwrap();
        assert!(loss.is_finite());
        assert!(acc > 0.7, "gradual acc {acc}");
    }
}
