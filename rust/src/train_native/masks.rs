//! Mask generators for every pattern Table 1 compares, on one (rows × cols)
//! weight matrix at a common sparsity.

use crate::sparsity::bsr::BsrMatrix;
use crate::sparsity::csr::CsrMatrix;
use crate::sparsity::memory::Pattern;
use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask};
use crate::util::rng::Rng;

/// Sample a 0/1 mask (row-major rows × cols) of the given pattern at
/// dyadic sparsity `sp`. RBGP4 picks a feasible factorization automatically
/// (G_r = (4,1), G_i square, G_o absorbs the rest — the Table-2 shape).
pub fn pattern_mask(
    pattern: Pattern,
    rows: usize,
    cols: usize,
    sp: f64,
    rng: &mut Rng,
) -> anyhow::Result<Vec<f32>> {
    match pattern {
        Pattern::Dense => Ok(vec![1.0; rows * cols]),
        Pattern::Unstructured => {
            let csr = CsrMatrix::random_row_uniform(rows, cols, sp, rng);
            Ok(csr
                .to_dense()
                .iter()
                .map(|&v| if v != 0.0 { 1.0 } else { 0.0 })
                .collect())
        }
        Pattern::Block(bh, bw) => {
            let bsr = BsrMatrix::random_block_uniform(rows, cols, bh, bw, sp, rng);
            // Blocks are dense inside: any stored position is on the mask.
            let mut mask = vec![0.0f32; rows * cols];
            for bi in 0..bsr.block_rows() {
                for k in bsr.indptr[bi]..bsr.indptr[bi + 1] {
                    let bj = bsr.indices[k];
                    for i in 0..bh {
                        let row = (bi * bh + i) * cols + bj * bw;
                        for v in &mut mask[row..row + bw] {
                            *v = 1.0;
                        }
                    }
                }
            }
            Ok(mask)
        }
        Pattern::Rbgp4 => {
            let cfg = rbgp4_factorization(rows, cols, sp)?;
            let mask = Rbgp4Mask::sample(cfg, rng)?;
            Ok(mask.dense())
        }
    }
}

/// Feasible RBGP4 factorization of (rows × cols) at total sparsity `sp`,
/// splitting evenly between G_o and G_i when possible (the paper's default
/// benchmarking split), else putting everything on one sparse graph.
pub fn rbgp4_factorization(rows: usize, cols: usize, sp: f64) -> anyhow::Result<Rbgp4Config> {
    // Candidate (sp_o, sp_i) splits whose product of densities matches sp.
    let splits: &[(f64, f64)] = match sp {
        x if (x - 0.5).abs() < 1e-9 => &[(0.5, 0.0), (0.0, 0.5)],
        x if (x - 0.75).abs() < 1e-9 => &[(0.5, 0.5), (0.75, 0.0), (0.0, 0.75)],
        x if (x - 0.875).abs() < 1e-9 => &[(0.75, 0.5), (0.5, 0.75), (0.875, 0.0)],
        x if (x - 0.9375).abs() < 1e-9 => &[(0.75, 0.75), (0.875, 0.5), (0.5, 0.875)],
        x if x == 0.0 => &[(0.0, 0.0)],
        _ => anyhow::bail!("non-dyadic sparsity {sp}"),
    };
    for &(sp_o, sp_i) in splits {
        for gi in [32usize, 16, 8, 4] {
            for gr_u in [4usize, 2, 1] {
                if rows % (gr_u * gi) != 0 || cols % gi != 0 {
                    continue;
                }
                let cfg = Rbgp4Config {
                    go: GraphSpec::new(rows / (gr_u * gi), cols / gi, sp_o),
                    gr: (gr_u, 1),
                    gi: GraphSpec::new(gi, gi, sp_i),
                    gb: (1, 1),
                };
                if cfg.validate().is_ok()
                    && crate::graph::lift::sparse_biregular_by_lifts(
                        cfg.go.nu, cfg.go.nv, sp_o, &mut Rng::new(0),
                    )
                    .is_ok()
                    && crate::graph::lift::sparse_biregular_by_lifts(
                        gi, gi, sp_i, &mut Rng::new(0),
                    )
                    .is_ok()
                {
                    return Ok(cfg);
                }
            }
        }
    }
    anyhow::bail!("no feasible RBGP4 factorization for {rows}x{cols} at sp={sp}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparsity_of(mask: &[f32]) -> f64 {
        1.0 - mask.iter().filter(|&&v| v != 0.0).count() as f64 / mask.len() as f64
    }

    #[test]
    fn all_patterns_hit_target_sparsity() {
        let mut rng = Rng::new(17);
        for &sp in &[0.5, 0.75, 0.875] {
            for pat in [
                Pattern::Unstructured,
                Pattern::Block(4, 4),
                Pattern::Rbgp4,
            ] {
                let m = pattern_mask(pat, 256, 256, sp, &mut rng).unwrap();
                assert!(
                    (sparsity_of(&m) - sp).abs() < 0.02,
                    "{:?} sp={sp}: got {}",
                    pat.name(),
                    sparsity_of(&m)
                );
            }
        }
    }

    #[test]
    fn dense_mask_is_all_ones() {
        let mut rng = Rng::new(18);
        let m = pattern_mask(Pattern::Dense, 8, 8, 0.0, &mut rng).unwrap();
        assert!(m.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn rbgp4_factorization_shapes() {
        for &(r, c, sp) in &[(256usize, 256usize, 0.75f64), (512, 256, 0.875), (128, 128, 0.5)] {
            let cfg = rbgp4_factorization(r, c, sp).unwrap();
            assert_eq!(cfg.rows(), r);
            assert_eq!(cfg.cols(), c);
            assert!((cfg.sparsity() - sp).abs() < 1e-9);
        }
    }

    #[test]
    fn block_mask_is_blocky() {
        // Blocks are all-or-nothing and each block row holds the same
        // number of blocks (row-uniform; columns are free, like cuSparse).
        let mut rng = Rng::new(19);
        let m = pattern_mask(Pattern::Block(4, 4), 64, 64, 0.75, &mut rng).unwrap();
        for bi in 0..16 {
            let mut blocks_in_row = 0;
            for bj in 0..16 {
                let mut ones = 0;
                for i in 0..4 {
                    for j in 0..4 {
                        ones += (m[(bi * 4 + i) * 64 + bj * 4 + j] != 0.0) as usize;
                    }
                }
                assert!(ones == 0 || ones == 16, "partial block ({bi},{bj})");
                blocks_in_row += (ones == 16) as usize;
            }
            assert_eq!(blocks_in_row, 4, "block row {bi} not uniform");
        }
    }
}
