//! Rollout-path serving benchmarks: what the alias layer costs and what
//! the zero-downtime machinery delivers under load.
//!
//! Four scenarios on RBGP4 demo pools (two seeds → two models sharing the
//! dense-classifier structure in one plan cache):
//!
//! * `alias` — identical closed-loop load submitted directly to the
//!   concrete model vs through an alias: throughput and latency
//!   percentiles side by side. The alias adds one registry resolution and
//!   one per-request metrics record; the delta is the rollout tax every
//!   aliased request pays.
//! * `canary` — a 20% canary over distinct payloads: the measured canary
//!   fraction (deterministic per-request FNV hash) vs the configured
//!   percent.
//! * `shadow` — shadow mode doubles executed work on spare capacity:
//!   client throughput with mirrors on, completed divergence samples,
//!   mirrors dropped under load, and the divergence the mirror measured
//!   between the two seeds.
//! * `flip` — `rollout()` under sustained traffic: how long the atomic
//!   flip + drain + retire takes, with the zero-drop invariant asserted
//!   (no queue-full, deadline, or quota rejections anywhere in the run).
//!
//! Results are written to `BENCH_rollout.json` (in the cargo package
//! root, where `cargo bench` runs) so later rollout PRs can diff the
//! trajectory the same way serving PRs diff `BENCH_server.json`.
//!
//! `cargo bench --bench rollout_bench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::coordinator::{
    BatchModel, InferenceServer, NativeSparseModel, ServerConfig, SubmitOptions,
};
use rbgp::data::CifarLike;
use rbgp::kernels::PlanCache;
use rbgp::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const OUT_PATH: &str = "BENCH_rollout.json";
const CLIENTS: usize = 8;
const WORKERS: usize = 2;
const BATCH: usize = 16;
const CLASSES: usize = 16;
const CANARY_PCT: u8 = 20;

fn demo_factory(
    seed: u64,
    cache: Arc<PlanCache>,
) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
    move || {
        let mut m = NativeSparseModel::rbgp4_demo(CLASSES, BATCH, 1, seed, Arc::clone(&cache))?;
        m.warm()?;
        Ok(Box::new(m) as Box<dyn BatchModel>)
    }
}

/// One pool serving "v1" (default route target of alias "prod") with "v2"
/// registered alongside — the staging layout every scenario starts from.
fn start_pool(total: usize) -> (InferenceServer, Arc<PlanCache>) {
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "v1",
        demo_factory(0, Arc::clone(&cache)),
        ServerConfig {
            workers: WORKERS,
            queue_cap: 4 * total.max(1),
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .expect("server start");
    server
        .register_model("v2", demo_factory(1, Arc::clone(&cache)))
        .expect("register v2");
    server.set_alias("prod", "v1").expect("set alias");
    (server, cache)
}

/// Closed-loop load on one route; returns wall seconds and every
/// per-request latency in milliseconds.
fn drive(server: &InferenceServer, route: &str, total: usize) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(total);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let server = server.clone();
                let route = route.to_string();
                scope.spawn(move || {
                    let mut data = CifarLike::new(server.in_dim, server.classes, 100 + c as u64);
                    let mut lat = Vec::with_capacity(total / CLIENTS);
                    for _ in 0..total / CLIENTS {
                        let b = data.test_batch(1);
                        let t = Instant::now();
                        let logits = server
                            .infer_with(b.x, SubmitOptions::default().with_model(route.clone()))
                            .expect("infer");
                        assert_eq!(logits.len(), server.classes);
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            lat_ms.extend(h.join().expect("client thread"));
        }
    });
    (t0.elapsed().as_secs_f64(), lat_ms)
}

fn pct(sorted_ms: &[f64], p: f64) -> f64 {
    let idx = (p / 100.0 * (sorted_ms.len() - 1) as f64) as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

fn leg_json(requests: usize, wall_s: f64, mut lat_ms: Vec<f64>) -> (f64, f64, f64, Json) {
    lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let rps = requests as f64 / wall_s.max(1e-9);
    let (p50, p99) = (pct(&lat_ms, 50.0), pct(&lat_ms, 99.0));
    let mut j = Json::obj();
    j.set("requests", requests)
        .set("wall_s", wall_s)
        .set("throughput_rps", rps)
        .set("p50_ms", p50)
        .set("p99_ms", p99);
    (rps, p50, p99, j)
}

fn alias_stat(server: &InferenceServer) -> rbgp::coordinator::AliasStats {
    server
        .alias_stats()
        .into_iter()
        .find(|a| a.alias == "prod")
        .expect("prod alias stats")
}

fn main() {
    let fast = std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let total = if fast { 256 } else { 2048 };
    println!(
        "rollout bench — RBGP4 demo models, batch {BATCH}, {WORKERS} workers, \
         {CLIENTS} closed-loop clients, {total} requests per leg\n"
    );

    // ── alias overhead: direct vs aliased, same pool, same load ─────────
    let (server, _cache) = start_pool(total);
    let (direct_wall, direct_lat) = drive(&server, "v1", total);
    let (alias_wall, alias_lat) = drive(&server, "prod", total);
    let n = CLIENTS * (total / CLIENTS);
    let (direct_rps, direct_p50, direct_p99, direct_json) = leg_json(n, direct_wall, direct_lat);
    let (alias_rps, alias_p50, alias_p99, alias_json) = leg_json(n, alias_wall, alias_lat);
    let overhead_pct = (direct_rps / alias_rps.max(1e-9) - 1.0) * 100.0;
    println!(
        "alias overhead: direct {direct_rps:>8.1} req/s (p50 {direct_p50:.3} ms, p99 \
         {direct_p99:.3} ms) vs aliased {alias_rps:>8.1} req/s (p50 {alias_p50:.3} ms, \
         p99 {alias_p99:.3} ms) — {overhead_pct:+.1}% throughput tax"
    );

    // ── canary split: measured fraction vs configured percent ───────────
    let before = alias_stat(&server);
    server.set_canary("prod", "v2", CANARY_PCT).expect("set canary");
    let (canary_wall, _) = drive(&server, "prod", total);
    let after = alias_stat(&server);
    let canary_reqs = after.requests - before.requests;
    let canaried = after.canary - before.canary;
    let measured = canaried as f64 / canary_reqs.max(1) as f64;
    assert!(canaried > 0, "a {CANARY_PCT}% canary routed nothing over {canary_reqs} requests");
    println!(
        "canary split: {canaried}/{canary_reqs} requests on the canary leg — measured \
         {:.1}% vs configured {CANARY_PCT}% ({:.1} req/s)",
        measured * 100.0,
        canary_reqs as f64 / canary_wall.max(1e-9)
    );
    server.clear_canary("prod").expect("clear canary");

    // ── shadow amplification: mirrors on spare capacity ─────────────────
    let shadow_before = alias_stat(&server);
    server.set_shadow("prod", "v2").expect("set shadow");
    let (shadow_wall, _) = drive(&server, "prod", total);
    server.clear_shadow("prod").expect("clear shadow");
    // Give queued Low-priority mirrors a moment to drain so the sample
    // accounting reflects the whole phase, then snapshot.
    let deadline = Instant::now() + Duration::from_secs(30);
    let shadow = loop {
        let s = alias_stat(&server);
        let done = s.shadow_samples + s.shadow_dropped
            >= (shadow_before.shadow_samples + shadow_before.shadow_dropped) + n;
        if done || Instant::now() >= deadline {
            break s;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    let samples = shadow.shadow_samples - shadow_before.shadow_samples;
    let dropped = shadow.shadow_dropped - shadow_before.shadow_dropped;
    let shadow_rps = n as f64 / shadow_wall.max(1e-9);
    println!(
        "shadow mode: {shadow_rps:>8.1} req/s with mirrors on — {samples} divergence \
         samples ({dropped} mirrors dropped), divergence mean {:.3e} max {:.3e}",
        shadow.shadow_mean, shadow.shadow_max
    );
    assert!(samples > 0, "no shadow mirror ever completed");

    // ── the flip: rollout under sustained traffic ───────────────────────
    let stop = Arc::new(AtomicBool::new(false));
    let answered = Arc::new(AtomicUsize::new(0));
    let (flip_ms, report) = std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let server = server.clone();
            let stop = Arc::clone(&stop);
            let answered = Arc::clone(&answered);
            scope.spawn(move || {
                let mut data = CifarLike::new(server.in_dim, server.classes, 500 + c as u64);
                while !stop.load(Ordering::Acquire) {
                    let b = data.test_batch(1);
                    let logits = server
                        .infer_with(b.x, SubmitOptions::default().with_model("prod"))
                        .expect("rollout must drop nothing");
                    assert_eq!(logits.len(), server.classes);
                    answered.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // Build up real in-flight traffic before flipping.
        while answered.load(Ordering::Relaxed) < CLIENTS * 4 {
            std::thread::yield_now();
        }
        let t0 = Instant::now();
        let report = server.rollout("prod", "v2").expect("rollout");
        let flip_ms = t0.elapsed().as_secs_f64() * 1e3;
        // Keep the flipped alias under load briefly, then stop.
        let target = answered.load(Ordering::Relaxed) + CLIENTS * 4;
        while answered.load(Ordering::Relaxed) < target {
            std::thread::yield_now();
        }
        stop.store(true, Ordering::Release);
        (flip_ms, report)
    });
    assert_eq!(report.model, "v1");
    assert_eq!(report.evicted_structures.len(), 1, "{report:?}");
    assert_eq!(report.retained_structures.len(), 1, "{report:?}");
    let (rej_full, rej_late) = server.rejected();
    let rej_quota = server.rejected_quota();
    assert_eq!(
        (rej_full, rej_late, rej_quota),
        (0, 0, 0),
        "zero-downtime invariant: nothing may be rejected across the rollout"
    );
    println!(
        "flip: rollout('prod' → 'v2') took {flip_ms:.1} ms under load — {} in-flight \
         drained, {} structure evicted / {} retained, 0 rejections",
        report.drained_requests,
        report.evicted_structures.len(),
        report.retained_structures.len()
    );
    server.shutdown();

    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("batch", BATCH)
        .set("classes", CLASSES)
        .set("workers", WORKERS)
        .set("clients", CLIENTS)
        .set("requests_per_leg", total)
        .set("fast_mode", fast);
    let mut alias_doc = Json::obj();
    alias_doc
        .set("direct", direct_json)
        .set("aliased", alias_json)
        .set("throughput_tax_pct", overhead_pct);
    let mut canary_doc = Json::obj();
    canary_doc
        .set("configured_pct", CANARY_PCT as usize)
        .set("requests", canary_reqs)
        .set("canaried", canaried)
        .set("measured_fraction", measured);
    let mut shadow_doc = Json::obj();
    shadow_doc
        .set("throughput_rps", shadow_rps)
        .set("samples", samples)
        .set("dropped", dropped)
        .set("divergence_mean", shadow.shadow_mean)
        .set("divergence_max", shadow.shadow_max);
    let mut flip_doc = Json::obj();
    flip_doc
        .set("flip_ms", flip_ms)
        .set("drained_requests", report.drained_requests)
        .set("evicted_structures", report.evicted_structures.len())
        .set("retained_structures", report.retained_structures.len())
        .set("evicted_plans", report.evicted_plans);
    doc.set("bench", "rollout_bench")
        .set("config", meta)
        .set("alias", alias_doc)
        .set("canary", canary_doc)
        .set("shadow", shadow_doc)
        .set("flip", flip_doc);
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
