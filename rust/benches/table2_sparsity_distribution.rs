//! Bench: regenerate the paper's **Table 2** (runtime vs sparsity split
//! between G_o and G_i). Prints paper / V100-model / measured columns.
//!
//! `cargo bench --bench table2_sparsity_distribution`
//! Env: RBGP_MEASURE_N (default 1024; 4096 reproduces the paper's size but
//! takes minutes on CPU), RBGP_BENCH_FAST=1 for a quick pass.

use rbgp::bench_harness::table2;

fn main() {
    let n: usize = std::env::var("RBGP_MEASURE_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    println!("{}", table2::run(n, 0).render());
}
