//! Bench: regenerate the paper's **Table 2** (runtime vs sparsity split
//! between G_o and G_i). Prints paper / V100-model / measured columns.
//!
//! `cargo bench --bench table2_sparsity_distribution`
//! Env: RBGP_MEASURE_N (default 1024; 4096 reproduces the paper's size but
//! takes minutes on CPU), RBGP_BENCH_FAST=1 for a quick pass,
//! RBGP_TUNE=quick|full adds a tuned-schedule column beside the heuristic.

use rbgp::bench_harness::table2;
use rbgp::kernels::TuneMode;

fn main() {
    let n: usize = std::env::var("RBGP_MEASURE_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let tune = match std::env::var("RBGP_TUNE").ok().as_deref() {
        None | Some("off") | Some("") => None,
        Some(m) => Some(TuneMode::parse(m).expect("RBGP_TUNE: off|quick|full")),
    };
    println!("{}", table2::run_tuned(n, 0, tune).render());
}
