//! Bench: regenerate the paper's **Table 3** (runtime vs row repetition
//! from the complete graphs G_r and G_b, G_t fixed at (128, 32)).
//!
//! `cargo bench --bench table3_row_repetition`
//! Env: RBGP_MEASURE_N (default 1024), RBGP_BENCH_FAST=1,
//! RBGP_TUNE=quick|full adds a tuned-schedule column beside the heuristic.

use rbgp::bench_harness::table3;
use rbgp::kernels::TuneMode;

fn main() {
    let n: usize = std::env::var("RBGP_MEASURE_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let tune = match std::env::var("RBGP_TUNE").ok().as_deref() {
        None | Some("off") | Some("") => None,
        Some(m) => Some(TuneMode::parse(m).expect("RBGP_TUNE: off|quick|full")),
    };
    println!("{}", table3::run_tuned(n, 0, tune).render());
}
