//! Bench: regenerate the paper's **Table 1** memory + time columns for
//! VGG19 and WideResNet-40-4, and *measure* the per-layer SDMM kernels on
//! this CPU for the largest layers of each network (same ordering claim at
//! local scale: unstructured > block > RBGP4).
//!
//! `cargo bench --bench table1_layers`   (RBGP_BENCH_FAST=1 for quick pass)

use rbgp::bench_harness::report::{ms, Table};
use rbgp::bench_harness::table1;
use rbgp::kernels::{bsr_sdmm_parallel, csr_sdmm_parallel, rbgp4mm_parallel};
use rbgp::models::vgg::vgg19;
use rbgp::sparsity::bsr::BsrMatrix;
use rbgp::sparsity::csr::CsrMatrix;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use rbgp::util::rng::Rng;
use rbgp::util::threadpool::default_threads;
use rbgp::util::timing::{bench_fn, BenchConfig};

fn main() {
    // Model columns (exact memory + V100 estimates) for both networks.
    for t in table1::run() {
        println!("{}", t.render());
    }

    // Measured pattern comparison on a representative VGG19 layer shape
    // (conv10: 512x4608 weights; batch scaled down to keep CPU time sane).
    let net = vgg19(10);
    let layer = net.layers[9];
    let batch = 4usize; // paper uses 256; N scales linearly for all kernels
    let shape = layer.sdmm_shape(batch);
    let (m, k, n) = (shape.m, shape.k, shape.n);
    println!("## Measured per-layer SDMM on this CPU — {} (m={m}, k={k}, n={n})\n", layer.name);

    let sp = 0.875;
    let mut rng = Rng::new(11);
    let threads = default_threads();
    let cfg = BenchConfig::from_env();
    let i = rng.normal_vec_f32(k * n, 1.0);
    let mut o = vec![0.0f32; m * n];

    let mut table = Table::new(
        &format!("{} @ {:.1}% sparsity", layer.name, sp * 100.0),
        &["pattern", "measured ms", "vs unstructured"],
    );

    let csr = CsrMatrix::random_row_uniform(m, k, sp, &mut rng);
    let t_csr = bench_fn(&cfg, || {
        csr_sdmm_parallel(&csr, &i, &mut o, n, threads);
        std::hint::black_box(&o);
    })
    .median;

    let bsr = BsrMatrix::random_block_uniform(m, k, 4, 4, sp, &mut rng);
    let t_bsr = bench_fn(&cfg, || {
        bsr_sdmm_parallel(&bsr, &i, &mut o, n, threads);
        std::hint::black_box(&o);
    })
    .median;

    // RBGP4 factorization of the same (m, k) at the same total sparsity.
    let rb_cfg = Rbgp4Config {
        go: GraphSpec::new(m / 128, k / 32, 0.75),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, 0.5),
        gb: (1, 1),
    };
    assert_eq!((rb_cfg.rows(), rb_cfg.cols()), (m, k));
    assert!((rb_cfg.sparsity() - sp).abs() < 1e-9);
    let mask = Rbgp4Mask::sample(rb_cfg, &mut rng).expect("mask");
    let w = Rbgp4Matrix::random(mask, &mut rng);
    let t_rb = bench_fn(&cfg, || {
        rbgp4mm_parallel(&w, &i, &mut o, n, threads);
        std::hint::black_box(&o);
    })
    .median;

    table.row(vec!["Unstructured (CSR)".into(), ms(t_csr), "1.0x".into()]);
    table.row(vec![
        "Block (BSR 4x4)".into(),
        ms(t_bsr),
        format!("{:.1}x", t_csr / t_bsr),
    ]);
    table.row(vec![
        "RBGP4".into(),
        ms(t_rb),
        format!("{:.1}x", t_csr / t_rb),
    ]);
    println!("{}", table.render());
}
