//! Microbenchmarks of every SDMM kernel variant — the perf-iteration
//! harness used for EXPERIMENTS.md §Perf (L3). Reports median ± MAD so
//! before/after comparisons between optimization steps are meaningful.
//!
//! `cargo bench --bench kernels_microbench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::kernels::bsr_sdmm::{bsr_sdmm, bsr_sdmm_parallel};
use rbgp::kernels::csr_sdmm::{csr_sdmm, csr_sdmm_parallel};
use rbgp::kernels::dense::{gemm_blocked, gemm_naive, gemm_parallel};
use rbgp::kernels::rbgp4mm::{rbgp4mm, rbgp4mm_naive, rbgp4mm_parallel};
use rbgp::sparsity::bsr::BsrMatrix;
use rbgp::sparsity::csr::CsrMatrix;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use rbgp::util::rng::Rng;
use rbgp::util::threadpool::default_threads;
use rbgp::util::timing::{bench_fn, report_row, BenchConfig};

fn main() {
    let n = 1024usize; // square SDMM at n³
    let sp = 0.875;
    let threads = default_threads();
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(3);

    println!("kernels microbench — SDMM {n}³, sparsity {:.1}%, {threads} threads\n", sp * 100.0);

    let i = rng.normal_vec_f32(n * n, 1.0);
    let mut o = vec![0.0f32; n * n];

    // Dense family.
    let wd = rng.normal_vec_f32(n * n, 1.0);
    if n <= 512 {
        let s = bench_fn(&cfg, || {
            gemm_naive(&wd, &i, &mut o, n, n, n);
            std::hint::black_box(&o);
        });
        println!("{}", report_row("dense/naive", &s));
    }
    let s = bench_fn(&cfg, || {
        gemm_blocked(&wd, &i, &mut o, n, n, n);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("dense/blocked (1 thread)", &s));
    let s = bench_fn(&cfg, || {
        gemm_parallel(&wd, &i, &mut o, n, n, n, threads);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("dense/parallel", &s));

    // Unstructured CSR.
    let csr = CsrMatrix::random_row_uniform(n, n, sp, &mut rng);
    let s = bench_fn(&cfg, || {
        csr_sdmm(&csr, &i, &mut o, n);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("csr/serial", &s));
    let s = bench_fn(&cfg, || {
        csr_sdmm_parallel(&csr, &i, &mut o, n, threads);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("csr/parallel", &s));

    // Block BSR (4,4).
    let bsr = BsrMatrix::random_block_uniform(n, n, 4, 4, sp, &mut rng);
    let s = bench_fn(&cfg, || {
        bsr_sdmm(&bsr, &i, &mut o, n);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("bsr/serial", &s));
    let s = bench_fn(&cfg, || {
        bsr_sdmm_parallel(&bsr, &i, &mut o, n, threads);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("bsr/parallel", &s));

    // RBGP4 at the same total sparsity (best Table-2 split: G_o-heavy).
    let rb_cfg = Rbgp4Config {
        go: GraphSpec::new(8, 32, 0.75),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, 0.5),
        gb: (1, 1),
    };
    assert!((rb_cfg.sparsity() - sp).abs() < 1e-9);
    let mask = Rbgp4Mask::sample(rb_cfg, &mut rng).expect("mask");
    let w = Rbgp4Matrix::random(mask, &mut rng);
    let s = bench_fn(&cfg, || {
        rbgp4mm_naive(&w, &i, &mut o, n);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("rbgp4mm/naive", &s));
    let s = bench_fn(&cfg, || {
        rbgp4mm(&w, &i, &mut o, n);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("rbgp4mm/packed (1 thread)", &s));
    let s = bench_fn(&cfg, || {
        rbgp4mm_parallel(&w, &i, &mut o, n, threads);
        std::hint::black_box(&o);
    });
    println!("{}", report_row("rbgp4mm/parallel", &s));
}
