//! Microbenchmarks of every SDMM kernel family through the `SparseKernel`
//! trait — the perf-iteration harness used for EXPERIMENTS.md §Perf.
//!
//! For each registered family × batch size × thread count the harness
//! reports three numbers (median ± MAD):
//!
//! * **plan**    — time to build the execution plan (`build_plan`), i.e.
//!   the cost the plan cache amortizes away;
//! * **execute** — time to run from a prebuilt plan (the cached hot path);
//! * **per-call** — the historical free-function path that re-derives
//!   structure and reallocates scratch every call (the seed baseline).
//!
//! Results are also written to `BENCH_kernels.json` (in the cargo package
//! root, where `cargo bench` runs) so future PRs have a perf trajectory:
//! each row records plan-build ms, execute ms, per-call ms, GFLOP/s of the
//! cached path, and the cached-vs-per-call speedup.
//!
//! `cargo bench --bench kernels_microbench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::kernels::plan::{PlanRequest, SparseMatrix};
use rbgp::kernels::registry::KernelRegistry;
use rbgp::kernels::{
    bsr_sdmm, bsr_sdmm_parallel, csr_sdmm, csr_sdmm_parallel, gemm_blocked, gemm_parallel,
    rbgp4mm, rbgp4mm_parallel,
};
use rbgp::sparsity::bsr::BsrMatrix;
use rbgp::sparsity::csr::CsrMatrix;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use rbgp::util::json::Json;
use rbgp::util::rng::Rng;
use rbgp::util::threadpool::default_threads;
use rbgp::util::timing::{bench_fn, BenchConfig, BenchStats};

const OUT_PATH: &str = "BENCH_kernels.json";

struct Row {
    kernel: &'static str,
    threads: usize,
    n: usize,
    plan_build: BenchStats,
    execute: BenchStats,
    percall: BenchStats,
    gflops: f64,
    speedup_vs_percall: f64,
}

impl Row {
    fn to_json(&self, m: usize, k: usize, sparsity: f64) -> Json {
        let mut j = Json::obj();
        j.set("kernel", self.kernel)
            .set("threads", self.threads)
            .set("m", m)
            .set("k", k)
            .set("n", self.n)
            .set("sparsity", sparsity)
            .set("plan_build_ms", self.plan_build.median_ms())
            .set("execute_ms", self.execute.median_ms())
            .set("execute_mad_ms", self.execute.mad * 1e3)
            .set("percall_ms", self.percall.median_ms())
            .set("gflops", self.gflops)
            .set("speedup_vs_percall", self.speedup_vs_percall);
        j
    }

    fn print(&self) {
        println!(
            "{:<10} t={:<2} n={:<5} plan {:>9.4} ms   execute {:>9.3} ms ±{:>7.3}   \
             per-call {:>9.3} ms   {:>7.2} GFLOP/s   cached {:>5.2}x vs per-call",
            self.kernel,
            self.threads,
            self.n,
            self.plan_build.median_ms(),
            self.execute.median_ms(),
            self.execute.mad * 1e3,
            self.percall.median_ms(),
            self.gflops,
            self.speedup_vs_percall,
        );
    }
}

#[allow(clippy::too_many_arguments)]
fn bench_family(
    registry: &KernelRegistry,
    cfg: &BenchConfig,
    w: &SparseMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    threads: usize,
    percall: &mut dyn FnMut(&[f32], &mut [f32]),
) -> Row {
    let kernel = registry.for_matrix(w).expect("registered kernel");
    let req = PlanRequest { n, threads };

    let plan_build = bench_fn(cfg, || {
        let plan = kernel.build_plan(w, &req).expect("plan");
        std::hint::black_box(&plan);
    });

    let mut plan = kernel.build_plan(w, &req).expect("plan");
    let execute = bench_fn(cfg, || {
        kernel.execute(w, &mut plan, i, o, n).expect("execute");
        std::hint::black_box(&o);
    });

    let percall = bench_fn(cfg, || {
        percall(i, o);
        std::hint::black_box(&o);
    });

    Row {
        kernel: kernel.name(),
        threads,
        n,
        gflops: w.flops(n) / execute.median / 1e9,
        speedup_vs_percall: percall.median / execute.median,
        plan_build,
        execute,
        percall,
    }
}

fn main() {
    let (m, k) = (1024usize, 1024usize);
    let sp = 0.875;
    let par = default_threads();
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(3);

    println!(
        "kernels microbench — SDMM ({m}×{k})·({k}×n), sparsity {:.1}%, parallel = {par} threads\n",
        sp * 100.0
    );

    // Weight operands, one per family, all at the same shape/sparsity
    // (dense ignores sparsity, as cuBLAS computes every element).
    let dense = SparseMatrix::dense(rng.normal_vec_f32(m * k, 1.0), m, k);
    let csr = SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, sp, &mut rng));
    let bsr = SparseMatrix::Bsr(BsrMatrix::random_block_uniform(m, k, 4, 4, sp, &mut rng));
    // RBGP4 at the same total sparsity (best Table-2 split: G_o-heavy).
    let rb_cfg = Rbgp4Config {
        go: GraphSpec::new(8, 32, 0.75),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, 0.5),
        gb: (1, 1),
    };
    assert!((rb_cfg.sparsity() - sp).abs() < 1e-9);
    let mask = Rbgp4Mask::sample(rb_cfg, &mut rng).expect("mask");
    let rbgp = SparseMatrix::Rbgp4(Rbgp4Matrix::random(mask, &mut rng));

    let registry = KernelRegistry::builtin();
    let ns = [256usize, 1024];
    let thread_counts = [1usize, par];
    let mut rows: Vec<Row> = Vec::new();

    for &n in &ns {
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o = vec![0.0f32; m * n];
        for &t in &thread_counts {
            for w in [&dense, &csr, &bsr, &rbgp] {
                // The per-call baseline: the seed's free-function path that
                // re-derives structure / reallocates scratch every call.
                let mut percall: Box<dyn FnMut(&[f32], &mut [f32])> = match w {
                    SparseMatrix::Dense { data, rows, cols } => {
                        let (data, rows, cols) = (data.clone(), *rows, *cols);
                        if t > 1 {
                            Box::new(move |i, o| gemm_parallel(&data, i, o, rows, cols, n, t))
                        } else {
                            Box::new(move |i, o| gemm_blocked(&data, i, o, rows, cols, n))
                        }
                    }
                    SparseMatrix::Csr(mtx) => {
                        let mtx = mtx.clone();
                        if t > 1 {
                            Box::new(move |i, o| csr_sdmm_parallel(&mtx, i, o, n, t))
                        } else {
                            Box::new(move |i, o| csr_sdmm(&mtx, i, o, n))
                        }
                    }
                    SparseMatrix::Bsr(mtx) => {
                        let mtx = mtx.clone();
                        if t > 1 {
                            Box::new(move |i, o| bsr_sdmm_parallel(&mtx, i, o, n, t))
                        } else {
                            Box::new(move |i, o| bsr_sdmm(&mtx, i, o, n))
                        }
                    }
                    SparseMatrix::Rbgp4(mtx) => {
                        let mtx = mtx.clone();
                        if t > 1 {
                            Box::new(move |i, o| rbgp4mm_parallel(&mtx, i, o, n, t))
                        } else {
                            Box::new(move |i, o| rbgp4mm(&mtx, i, o, n))
                        }
                    }
                };
                let row = bench_family(&registry, &cfg, w, &i, &mut o, n, t, percall.as_mut());
                row.print();
                rows.push(row);
            }
            println!();
        }
    }

    // Persist the trajectory for future PRs.
    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("m", m)
        .set("k", k)
        .set("sparsity", sp)
        .set("parallel_threads", par)
        .set(
            "fast_mode",
            std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false),
        );
    doc.set("bench", "kernels_microbench").set("config", meta).set(
        "rows",
        Json::Arr(rows.iter().map(|r| r.to_json(m, k, sp)).collect()),
    );
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {OUT_PATH} ({} rows)", rows.len()),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
