//! Microbenchmarks of every SDMM kernel family through the `SparseKernel`
//! trait — the perf-iteration harness used for EXPERIMENTS.md §Perf.
//!
//! For each registered family × batch size × thread count the harness
//! reports (median ± MAD):
//!
//! * **plan**    — time to build the execution plan (`build_plan`), i.e.
//!   the cost the plan cache amortizes away — under the selected tune mode
//!   this includes the schedule search;
//! * **execute** — time to run from a prebuilt plan (the cached hot path);
//! * **per-call** — the historical free-function path that re-derives
//!   structure and reallocates scratch every call (the seed baseline);
//! * roofline placement — arithmetic intensity (flops/byte), achieved
//!   bandwidth, and the fraction of the machine's roofline-attainable
//!   GFLOP/s the kernel reaches (probe: STREAM triad + FMA peak);
//! * **heuristic vs tuned** — GFLOP/s of the fixed-heuristic (`--tune
//!   off`) plan next to the autotuned one.
//!
//! Results are also written to `BENCH_kernels.json` (in the cargo package
//! root, where `cargo bench` runs) so future PRs have a perf trajectory.
//!
//! `cargo bench --bench kernels_microbench [-- --tune off|quick|full]
//! [-- --tune-cache FILE]` (RBGP_BENCH_FAST=1 quick pass; tune defaults to
//! quick). With `--tune-cache` the persistent [`TuneCache`] is consulted:
//! rows whose winner is already recorded build with zero search reps (the
//! per-row `search_reps` field in the JSON makes warm vs cold visible),
//! and the **plan** column reports the warm-cache build cost rather than
//! the search cost.

use std::sync::Arc;

use rbgp::kernels::autotune::{search_reps, TuneCache, TuneMode};
use rbgp::kernels::plan::{PlanRequest, SparseMatrix};
use rbgp::kernels::registry::KernelRegistry;
use rbgp::kernels::{
    bsr_sdmm, bsr_sdmm_parallel, csr_sdmm, csr_sdmm_parallel, gemm_blocked, gemm_parallel,
    machine_probe, rbgp4mm, rbgp4mm_parallel,
};
use rbgp::sparsity::bsr::BsrMatrix;
use rbgp::sparsity::csr::CsrMatrix;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use rbgp::util::json::Json;
use rbgp::util::rng::Rng;
use rbgp::util::threadpool::default_threads;
use rbgp::util::timing::{bench_fn, BenchConfig, BenchStats};

const OUT_PATH: &str = "BENCH_kernels.json";

struct Row {
    kernel: &'static str,
    threads: usize,
    n: usize,
    plan_build: BenchStats,
    execute: BenchStats,
    percall: BenchStats,
    gflops: f64,
    gflops_heuristic: f64,
    speedup_vs_percall: f64,
    ai_flops_per_byte: f64,
    achieved_gbps: f64,
    roofline_fraction: f64,
    tuned_params: String,
    /// Measurement executions the schedule search spent building this
    /// row's tuned plan — 0 when the winner came from a warm `TuneCache`.
    search_reps: usize,
}

impl Row {
    fn to_json(&self, m: usize, k: usize, sparsity: f64) -> Json {
        let mut j = Json::obj();
        j.set("kernel", self.kernel)
            .set("threads", self.threads)
            .set("m", m)
            .set("k", k)
            .set("n", self.n)
            .set("sparsity", sparsity)
            .set("plan_build_ms", self.plan_build.median_ms())
            .set("execute_ms", self.execute.median_ms())
            .set("execute_mad_ms", self.execute.mad * 1e3)
            .set("percall_ms", self.percall.median_ms())
            .set("gflops", self.gflops)
            .set("gflops_heuristic", self.gflops_heuristic)
            .set("speedup_vs_percall", self.speedup_vs_percall)
            .set("ai_flops_per_byte", self.ai_flops_per_byte)
            .set("achieved_gbps", self.achieved_gbps)
            .set("roofline_fraction", self.roofline_fraction)
            .set("tuned_params", self.tuned_params.as_str())
            .set("search_reps", self.search_reps);
        j
    }

    fn print(&self) {
        println!(
            "{:<10} t={:<2} n={:<5} plan {:>9.4} ms   execute {:>9.3} ms ±{:>7.3}   \
             per-call {:>9.3} ms   {:>7.2} GFLOP/s (heur {:>7.2})   cached {:>5.2}x",
            self.kernel,
            self.threads,
            self.n,
            self.plan_build.median_ms(),
            self.execute.median_ms(),
            self.execute.mad * 1e3,
            self.percall.median_ms(),
            self.gflops,
            self.gflops_heuristic,
            self.speedup_vs_percall,
        );
        println!(
            "{:<10}                AI {:>6.2} flop/B   {:>7.2} GB/s   roofline {:>5.1}%   [{}] \
             ({} search reps)",
            "",
            self.ai_flops_per_byte,
            self.achieved_gbps,
            self.roofline_fraction * 100.0,
            self.tuned_params,
            self.search_reps,
        );
    }
}

#[allow(clippy::too_many_arguments)]
fn bench_family(
    registry: &KernelRegistry,
    cfg: &BenchConfig,
    w: &SparseMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    threads: usize,
    tune: TuneMode,
    tune_cache: Option<&Arc<TuneCache>>,
    percall: &mut dyn FnMut(&[f32], &mut [f32]),
) -> Row {
    let kernel = registry.for_matrix(w).expect("registered kernel");
    let mut req = PlanRequest::new(n, threads).with_tune(tune);
    if let Some(tc) = tune_cache {
        req = req.with_tune_cache(Arc::clone(tc));
    }

    // The instrumented tuned build runs first, before any other build has
    // had the chance to record its winner into the cache: the rep delta is
    // therefore 0 exactly when this process started with the winner on
    // disk (the warm-start property the CI artifact exists to exercise).
    let reps_before = search_reps();
    let mut plan = kernel.build_plan(w, &req).expect("plan");
    let reps_spent = search_reps() - reps_before;

    let plan_build = bench_fn(cfg, || {
        let plan = kernel.build_plan(w, &req).expect("plan");
        std::hint::black_box(&plan);
    });

    // The fixed-heuristic baseline the tuner must not lose to.
    let off = PlanRequest::new(n, threads).with_tune(TuneMode::Off);
    let mut heuristic_plan = kernel.build_plan(w, &off).expect("heuristic plan");
    let heuristic = bench_fn(cfg, || {
        kernel
            .execute(w, &mut heuristic_plan, i, o, n)
            .expect("execute");
        std::hint::black_box(&o);
    });

    let execute = bench_fn(cfg, || {
        kernel.execute(w, &mut plan, i, o, n).expect("execute");
        std::hint::black_box(&o);
    });

    let percall = bench_fn(cfg, || {
        percall(i, o);
        std::hint::black_box(&o);
    });

    let gflops = w.flops(n) / execute.median / 1e9;
    let ai = w.arithmetic_intensity(n);
    Row {
        kernel: kernel.name(),
        threads,
        n,
        gflops,
        gflops_heuristic: w.flops(n) / heuristic.median / 1e9,
        speedup_vs_percall: percall.median / execute.median,
        ai_flops_per_byte: ai,
        achieved_gbps: w.bytes_touched(n) / execute.median / 1e9,
        roofline_fraction: gflops / machine_probe().attainable_gflops(ai),
        tuned_params: plan
            .tuned
            .as_ref()
            .map(|t| t.params.clone())
            .unwrap_or_else(|| "heuristic".to_string()),
        search_reps: reps_spent,
        plan_build,
        execute,
        percall,
    }
}

fn tune_from_args() -> TuneMode {
    let args: Vec<String> = std::env::args().collect();
    for pair in args.windows(2) {
        if pair[0] == "--tune" {
            return TuneMode::parse(&pair[1]).expect("--tune off|quick|full");
        }
    }
    TuneMode::default()
}

/// `--tune-cache FILE`: persist tuned winners across bench runs (the CI
/// warm-start artifact). Returns the opened cache and whether the file
/// held any usable entries before this run touched it.
fn tune_cache_from_args() -> Option<(Arc<TuneCache>, String, bool)> {
    let args: Vec<String> = std::env::args().collect();
    for pair in args.windows(2) {
        if pair[0] == "--tune-cache" {
            let cache = TuneCache::open(&pair[1]);
            let preexisting = !cache.is_empty();
            return Some((cache, pair[1].clone(), preexisting));
        }
    }
    None
}

fn main() {
    let (m, k) = (1024usize, 1024usize);
    let sp = 0.875;
    let par = default_threads();
    let cfg = BenchConfig::from_env();
    let tune = tune_from_args();
    let tune_cache = tune_cache_from_args();
    let mut rng = Rng::new(3);

    let probe = machine_probe();
    println!(
        "kernels microbench — SDMM ({m}×{k})·({k}×n), sparsity {:.1}%, parallel = {par} threads",
        sp * 100.0
    );
    println!(
        "machine probe: {:.2} GB/s stream, {:.2} GFLOP/s fma peak — tune mode {}",
        probe.peak_gbps,
        probe.peak_gflops,
        tune.name()
    );
    if let Some((cache, path, preexisting)) = &tune_cache {
        println!(
            "tune cache {path}: {} entries loaded ({} rejected), {}",
            cache.len(),
            cache.rejected_entries(),
            if *preexisting { "warm start" } else { "cold start" }
        );
    }
    println!();

    // Weight operands, one per family, all at the same shape/sparsity
    // (dense ignores sparsity, as cuBLAS computes every element).
    let dense = SparseMatrix::dense(rng.normal_vec_f32(m * k, 1.0), m, k);
    let csr = SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, sp, &mut rng));
    let bsr = SparseMatrix::Bsr(BsrMatrix::random_block_uniform(m, k, 4, 4, sp, &mut rng));
    // RBGP4 at the same total sparsity (best Table-2 split: G_o-heavy).
    let rb_cfg = Rbgp4Config {
        go: GraphSpec::new(8, 32, 0.75),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, 0.5),
        gb: (1, 1),
    };
    assert!((rb_cfg.sparsity() - sp).abs() < 1e-9);
    let mask = Rbgp4Mask::sample(rb_cfg, &mut rng).expect("mask");
    let rbgp = SparseMatrix::Rbgp4(Rbgp4Matrix::random(mask, &mut rng));

    let registry = KernelRegistry::builtin();
    let ns = [256usize, 1024];
    let thread_counts = [1usize, par];
    let mut rows: Vec<Row> = Vec::new();

    for &n in &ns {
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o = vec![0.0f32; m * n];
        for &t in &thread_counts {
            for w in [&dense, &csr, &bsr, &rbgp] {
                // The per-call baseline: the seed's free-function path that
                // re-derives structure / reallocates scratch every call.
                let mut percall: Box<dyn FnMut(&[f32], &mut [f32])> = match w {
                    SparseMatrix::Dense { data, rows, cols } => {
                        let (data, rows, cols) = (data.clone(), *rows, *cols);
                        if t > 1 {
                            Box::new(move |i, o| gemm_parallel(&data, i, o, rows, cols, n, t))
                        } else {
                            Box::new(move |i, o| gemm_blocked(&data, i, o, rows, cols, n))
                        }
                    }
                    SparseMatrix::Csr(mtx) => {
                        let mtx = mtx.clone();
                        if t > 1 {
                            Box::new(move |i, o| csr_sdmm_parallel(&mtx, i, o, n, t))
                        } else {
                            Box::new(move |i, o| csr_sdmm(&mtx, i, o, n))
                        }
                    }
                    SparseMatrix::Bsr(mtx) => {
                        let mtx = mtx.clone();
                        if t > 1 {
                            Box::new(move |i, o| bsr_sdmm_parallel(&mtx, i, o, n, t))
                        } else {
                            Box::new(move |i, o| bsr_sdmm(&mtx, i, o, n))
                        }
                    }
                    SparseMatrix::Rbgp4(mtx) => {
                        let mtx = mtx.clone();
                        if t > 1 {
                            Box::new(move |i, o| rbgp4mm_parallel(&mtx, i, o, n, t))
                        } else {
                            Box::new(move |i, o| rbgp4mm(&mtx, i, o, n))
                        }
                    }
                };
                let row = bench_family(
                    &registry,
                    &cfg,
                    w,
                    &i,
                    &mut o,
                    n,
                    t,
                    tune,
                    tune_cache.as_ref().map(|(c, _, _)| c),
                    percall.as_mut(),
                );
                row.print();
                rows.push(row);
            }
            println!();
        }
    }

    // Persist the trajectory for future PRs.
    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("m", m)
        .set("k", k)
        .set("sparsity", sp)
        .set("parallel_threads", par)
        .set("tune_mode", tune.name())
        .set("probe_peak_gbps", probe.peak_gbps)
        .set("probe_peak_gflops", probe.peak_gflops)
        .set(
            "fast_mode",
            std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false),
        );
    if let Some((cache, path, preexisting)) = &tune_cache {
        meta.set("tune_cache_path", path.as_str())
            .set("tune_cache_preexisting", *preexisting)
            .set("tune_cache_entries", cache.len());
    }
    doc.set("bench", "kernels_microbench").set("config", meta).set(
        "rows",
        Json::Arr(rows.iter().map(|r| r.to_json(m, k, sp)).collect()),
    );
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {OUT_PATH} ({} rows)", rows.len()),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
