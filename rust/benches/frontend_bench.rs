//! Network front-end benchmarks: what the TCP reactor costs over the
//! in-process submit path, how it scales with connections, and what the
//! bounded write buffer does to a reader that stops reading.
//!
//! Two parts on one RBGP4 demo pool (two models, one plan cache):
//!
//! * a **connections × skew grid** of closed-loop network clients — each
//!   connection round-trips requests through the reactor, either spread
//!   uniformly across both models or 90%-hot on one. Per cell:
//!   throughput, p50/p99 round-trip latency, and the front-end's
//!   accepted/rejected/shed accounting.
//! * a **slow reader**: a connection that sends a burst and never reads
//!   a byte, against a deliberately tiny write-buffer cap. Every
//!   completed response must be *shed* (bounded memory, counted in
//!   `frontend_totals`) instead of growing the buffer without bound.
//!
//! Results are written to `BENCH_frontend.json` (in the cargo package
//! root, where `cargo bench` runs) so later front-end PRs can diff the
//! trajectory the same way serving PRs diff `BENCH_server.json`.
//!
//! `cargo bench --bench frontend_bench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::coordinator::{
    BatchModel, Frontend, FrontendClient, FrontendConfig, InferenceServer, NativeSparseModel,
    Priority, Request, ServerConfig, Status,
};
use rbgp::data::CifarLike;
use rbgp::kernels::PlanCache;
use rbgp::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

const OUT_PATH: &str = "BENCH_frontend.json";
const WORKERS: usize = 2;
const BATCH: usize = 16;
const CLASSES: usize = 16;
const SLOW_READER_BURST: usize = 64;
const SLOW_WRITE_CAP: usize = 64; // smaller than any response frame

fn demo_factory(
    seed: u64,
    cache: Arc<PlanCache>,
) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
    move || {
        let mut m = NativeSparseModel::rbgp4_demo(CLASSES, BATCH, 1, seed, Arc::clone(&cache))?;
        m.warm()?;
        Ok(Box::new(m) as Box<dyn BatchModel>)
    }
}

fn start_pool(total: usize) -> InferenceServer {
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "v1",
        demo_factory(0, Arc::clone(&cache)),
        ServerConfig {
            workers: WORKERS,
            queue_cap: 4 * total.max(1),
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .expect("server start");
    server.register_model("v2", demo_factory(1, Arc::clone(&cache))).expect("register v2");
    server
}

/// Route for request `r` on connection `c` under the given hot-model
/// fraction (percent of traffic pinned to "v1").
fn route(hot_pct: usize, c: usize, r: usize) -> &'static str {
    if (c * 7919 + r * 104729) % 100 < hot_pct {
        "v1"
    } else {
        "v2"
    }
}

/// Closed-loop network load: `connections` clients, each round-tripping
/// its share of `total` requests through the reactor. Returns wall
/// seconds and per-request round-trip latencies in milliseconds.
fn drive(addr: std::net::SocketAddr, server: &InferenceServer, connections: usize, hot_pct: usize, total: usize) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(total);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..connections)
            .map(|c| {
                let server = server.clone();
                scope.spawn(move || {
                    let mut client = FrontendClient::connect(addr).expect("connect");
                    let mut data = CifarLike::new(server.in_dim, server.classes, 100 + c as u64);
                    let mut lat = Vec::with_capacity(total / connections);
                    for r in 0..total / connections {
                        let b = data.test_batch(1);
                        let t = Instant::now();
                        let resp = client
                            .infer(b.x, Some(route(hot_pct, c, r)), Priority::Normal, "bench", 0)
                            .expect("round trip");
                        assert_eq!(resp.status, Status::Ok, "bench request failed: {}", resp.detail);
                        assert_eq!(resp.payload.len(), server.classes);
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            lat_ms.extend(h.join().expect("client thread"));
        }
    });
    (t0.elapsed().as_secs_f64(), lat_ms)
}

fn pct(sorted_ms: &[f64], p: f64) -> f64 {
    let idx = (p / 100.0 * (sorted_ms.len() - 1) as f64) as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

fn main() {
    let fast = std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let total = if fast { 256 } else { 2048 };
    println!(
        "frontend bench — RBGP4 demo pool ({WORKERS} workers, batch {BATCH}), \
         TCP reactor, {total} requests per cell\n"
    );

    let server = start_pool(total);
    let fe = Frontend::start(server.clone(), FrontendConfig::default()).expect("frontend start");
    let addr = fe.local_addr();

    // ── connections × skew grid ─────────────────────────────────────────
    let mut cells: Vec<Json> = Vec::new();
    for &connections in &[2usize, 8] {
        for &(skew, hot_pct) in &[("uniform", 50usize), ("hot90", 90)] {
            let before = server.frontend_totals();
            let (wall_s, mut lat_ms) = drive(addr, &server, connections, hot_pct, total);
            let after = server.frontend_totals();
            let n = lat_ms.len();
            lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
            let rps = n as f64 / wall_s.max(1e-9);
            let (p50, p99) = (pct(&lat_ms, 50.0), pct(&lat_ms, 99.0));
            let (accepted, rejected, shed) =
                (after.0 - before.0, after.1 - before.1, after.2 - before.2);
            assert_eq!(accepted, n, "closed-loop Ok responses all count as accepted");
            assert_eq!((rejected, shed), (0, 0), "nothing rejects or sheds under closed loop");
            println!(
                "{connections:>2} conns, {skew:<7}: {rps:>8.1} req/s  p50 {p50:.3} ms  \
                 p99 {p99:.3} ms  ({accepted} accepted)"
            );
            let mut cell = Json::obj();
            cell.set("connections", connections)
                .set("skew", skew)
                .set("hot_pct", hot_pct)
                .set("requests", n)
                .set("wall_s", wall_s)
                .set("throughput_rps", rps)
                .set("p50_ms", p50)
                .set("p99_ms", p99)
                .set("accepted", accepted)
                .set("rejected", rejected)
                .set("shed", shed);
            cells.push(cell);
        }
    }
    fe.shutdown();

    // ── slow reader: bounded write buffer sheds, memory stays flat ──────
    // A dedicated front-end whose write-buffer cap is smaller than one
    // response frame: a peer that never reads gets every completed
    // response shed (and counted) instead of an unbounded buffer.
    let fe2 = Frontend::start(
        server.clone(),
        FrontendConfig { write_buf_cap: SLOW_WRITE_CAP, ..FrontendConfig::default() },
    )
    .expect("slow-reader frontend");
    let before = server.frontend_totals();
    let mut sink = FrontendClient::connect(fe2.local_addr()).expect("connect slow reader");
    let mut data = CifarLike::new(server.in_dim, server.classes, 999);
    for r in 0..SLOW_READER_BURST {
        let b = data.test_batch(1);
        sink.send(&Request {
            req_id: r as u64 + 1,
            priority: Priority::Normal,
            deadline_ms: 0,
            tenant: "sink".to_string(),
            model: Some("v1".to_string()),
            payload: b.x,
        })
        .expect("send burst");
    }
    // Never read a byte; wait for every response to complete and shed.
    let deadline = Instant::now() + Duration::from_secs(60);
    let shed = loop {
        let now = server.frontend_totals();
        if now.2 - before.2 >= SLOW_READER_BURST || Instant::now() >= deadline {
            break now.2 - before.2;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    assert_eq!(
        shed, SLOW_READER_BURST,
        "every response to a never-reading peer must shed against a {SLOW_WRITE_CAP}-byte cap"
    );
    println!(
        "\nslow reader: {SLOW_READER_BURST} requests, 0 bytes read — {shed} responses shed \
         (write buffer capped at {SLOW_WRITE_CAP} B)"
    );
    drop(sink);
    fe2.shutdown();
    server.shutdown();

    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("batch", BATCH)
        .set("classes", CLASSES)
        .set("workers", WORKERS)
        .set("requests_per_cell", total)
        .set("fast_mode", fast);
    let mut slow = Json::obj();
    slow.set("requests", SLOW_READER_BURST)
        .set("write_buf_cap", SLOW_WRITE_CAP)
        .set("shed", shed);
    doc.set("bench", "frontend_bench")
        .set("config", meta)
        .set("grid", Json::Arr(cells))
        .set("slow_reader", slow);
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
