//! Gradual structure induction: per-milestone plan-rebuild cost vs
//! steady-state execution — the trajectory for the mutable-structure
//! lifecycle (mask chain → structure hash → plan generation → eviction).
//!
//! A gradual run pays, at every milestone, what a fixed-mask run pays
//! once: evict the outgoing structure's plans and derive the incoming
//! structure's. This bench runs a full gradual training
//! (`NativeTrainer::run_gradual`), records each milestone's rebuild time
//! and eviction count, then measures the steady-state plan-path forward at
//! the final structure, so the rebuild cost can be read as "N forwards'
//! worth of work per milestone".
//!
//! Results go to `BENCH_gradual.json` (cargo package root, like
//! `BENCH_kernels.json` / `BENCH_server.json`) for future PRs to diff.
//!
//! `cargo bench --bench gradual_bench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::coordinator::{BatchModel, NativeTrainer};
use rbgp::train_native::{GradualSchedule, NativeTrainConfig};
use rbgp::util::json::Json;
use std::time::Instant;

const OUT_PATH: &str = "BENCH_gradual.json";
const IN_DIM: usize = 256;
const HIDDEN: usize = 256;
const CLASSES: usize = 16;
const BATCH: usize = 64;
const THREADS: usize = 2;
const SPARSITY: f64 = 0.75;
const SEED: u64 = 11;

fn main() {
    let fast = std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let steps = if fast { 80 } else { 400 };
    let schedule = GradualSchedule::default();
    println!(
        "gradual bench — MLP {IN_DIM}->{HIDDEN}->{CLASSES}, dense start → RBGP4 @ \
         {:.0}% sparsity, {} steps, milestones {:?}\n",
        SPARSITY * 100.0,
        steps,
        schedule.fractions
    );

    let config = NativeTrainConfig {
        steps,
        batch: BATCH,
        lr: 0.05,
        seed: SEED,
        ..NativeTrainConfig::default()
    };
    let mut trainer =
        NativeTrainer::new_gradual(IN_DIM, HIDDEN, CLASSES, SPARSITY, &schedule, config)
            .expect("gradual trainer")
            .with_threads(THREADS);
    let report = trainer.run_gradual().expect("gradual run");

    // Steady-state: the plan-path forward at the final structure, plans
    // already cached — the baseline a milestone's rebuild cost is paid
    // against.
    let mut model = trainer.serving_model(BATCH, THREADS).expect("serving model");
    model.warm().expect("warm");
    let x: Vec<f32> = (0..BATCH * IN_DIM)
        .map(|i| ((i % 23) as f32 - 11.0) / 11.0)
        .collect();
    let iters = if fast { 20 } else { 200 };
    for _ in 0..3 {
        model.forward(&x).expect("warm-up forward");
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        model.forward(&x).expect("forward");
    }
    let execute_s = t0.elapsed().as_secs_f64() / iters as f64;

    println!("\nsteady-state execute: {:.3} ms / batch-{BATCH} forward", execute_s * 1e3);
    println!(
        "{:>9} {:>6} {:>10} {:>10} {:>9} {:>13} {:>16}",
        "milestone", "step", "loss", "sparsity", "evicted", "rebuild ms", "≈ forwards"
    );
    let mut rows = Vec::new();
    for r in &report.milestones {
        let forwards_equiv = r.plan_rebuild_s / execute_s.max(1e-12);
        println!(
            "{:>9} {:>6} {:>10.4} {:>10.4} {:>9} {:>13.3} {:>16.1}",
            r.milestone,
            r.step + 1,
            r.loss,
            r.sparsity,
            r.evicted_plans,
            r.plan_rebuild_s * 1e3,
            forwards_equiv
        );
        let mut j = Json::obj();
        j.set("milestone", r.milestone)
            .set("step", r.step)
            .set("loss", r.loss as f64)
            .set("sparsity", r.sparsity)
            .set("structure_hash", format!("{:016x}", r.structure_hash))
            .set("evicted_plans", r.evicted_plans)
            .set("plan_rebuild_ms", r.plan_rebuild_s * 1e3)
            .set("rebuild_over_execute", forwards_equiv);
        rows.push(j);
    }

    let (hits, misses) = trainer.cache().stats();
    let (invalidations, evicted) = trainer.cache().eviction_stats();
    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("in_dim", IN_DIM)
        .set("hidden", HIDDEN)
        .set("classes", CLASSES)
        .set("batch", BATCH)
        .set("threads", THREADS)
        .set("sparsity", SPARSITY)
        .set("steps", steps)
        .set("seed", SEED)
        .set("fast_mode", fast)
        .set(
            "milestone_fractions",
            Json::Arr(schedule.fractions.iter().map(|&f| Json::Num(f)).collect()),
        );
    let mut cache = Json::obj();
    cache
        .set("hits", hits)
        .set("misses", misses)
        .set("invalidations", invalidations)
        .set("evicted_plans", evicted)
        .set("live_structures", trainer.cache().structures().len());
    doc.set("bench", "gradual_bench")
        .set("config", meta)
        .set("final_loss", report.final_loss as f64)
        .set("accuracy", report.accuracy)
        .set("steady_execute_ms", execute_s * 1e3)
        .set("cache", cache)
        .set("milestones", Json::Arr(rows));
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH} ({} milestones)", report.milestones.len()),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
