//! Multi-model serving throughput vs co-resident model count at fixed
//! offered load — the registry trajectory for the multi-tenant
//! `InferenceServer`.
//!
//! For each model count (1 / 2 / 4) the harness starts one worker pool,
//! registers that many RBGP4 demo models (distinct seeds → distinct
//! hidden-layer structures; the dense classifier structure is shared by
//! all), drives a fixed closed-loop load round-robining across the
//! models, and reports wall time, throughput, latency percentiles and —
//! the paper's amortization claim at the serving layer — plan-cache
//! builds, which must equal the number of **distinct structures**
//! (`models + 1`), not models × workers × layers.
//!
//! Two **skewed-traffic** scenarios ride along:
//!
//! * `skew.queue` — the acceptance check for the per-model queue index:
//!   one hot model piles `depth` entries in front of a handful of cold
//!   entries, and the bench times `pop_model_until("cold", …)` directly.
//!   With the O(depth) scan this cost grew linearly in the hot backlog;
//!   with the dual-view index the per-pop time must be independent of
//!   depth (the bench asserts the deep/shallow ratio stays far below the
//!   depth ratio).
//! * `skew.serving` — a 1-hot/1-cold pool under ~8:1 offered skew with a
//!   `FairShare(0.5)` quota on the hot model: reports cold-model latency
//!   percentiles, worker steal counts and quota rejections, so admission
//!   and work-stealing regressions are visible per-PR.
//!
//! Results are written to `BENCH_registry.json` (in the cargo package
//! root, where `cargo bench` runs) so future multi-tenant PRs — cache
//! sharding, NUMA-aware placement — can diff against this trajectory the
//! same way serving PRs diff against `BENCH_server.json`.
//!
//! `cargo bench --bench registry_bench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::coordinator::serving::queue::{Priority, QueuedRequest, RequestQueue};
use rbgp::coordinator::serving::registry::ModelClaim;
use rbgp::coordinator::{
    BatchModel, InferenceServer, ModelQuota, NativeSparseModel, ServeError, ServerConfig,
    SubmitOptions,
};
use rbgp::data::CifarLike;
use rbgp::kernels::PlanCache;
use rbgp::util::json::Json;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const OUT_PATH: &str = "BENCH_registry.json";
const CLIENTS: usize = 8;
const WORKERS: usize = 2;
const BATCH: usize = 16;
const CLASSES: usize = 16;

struct Row {
    models: usize,
    requests: usize,
    batches: usize,
    wall_s: f64,
    throughput_rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    occupancy: f64,
    cache_builds: usize,
    cache_hits: usize,
    structures: usize,
}

impl Row {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("models", self.models)
            .set("workers", WORKERS)
            .set("clients", CLIENTS)
            .set("batch", BATCH)
            .set("requests", self.requests)
            .set("batches", self.batches)
            .set("wall_s", self.wall_s)
            .set("throughput_rps", self.throughput_rps)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms)
            .set("p99_ms", self.p99_ms)
            .set("occupancy", self.occupancy)
            .set("cache_builds", self.cache_builds)
            .set("cache_hits", self.cache_hits)
            .set("structures", self.structures);
        j
    }

    fn print(&self) {
        println!(
            "models={:<2} {:>6} reqs in {:>5} batches  {:>8.1} req/s   \
             p50 {:>7.3} ms  p95 {:>7.3} ms  p99 {:>7.3} ms   occ {:>5.1}%   \
             {} builds for {} structures ({} hits)",
            self.models,
            self.requests,
            self.batches,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.occupancy * 100.0,
            self.cache_builds,
            self.structures,
            self.cache_hits,
        );
    }
}

fn demo_factory(
    seed: u64,
    cache: Arc<PlanCache>,
) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
    move || {
        let mut m = NativeSparseModel::rbgp4_demo(CLASSES, BATCH, 1, seed, Arc::clone(&cache))?;
        m.warm()?;
        Ok(Box::new(m) as Box<dyn BatchModel>)
    }
}

fn run_load(models: usize, total: usize) -> Row {
    // One shared cache for the whole pool *and* every model: each model's
    // hidden structure is derived once, the dense classifier once ever.
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "m0",
        demo_factory(0, Arc::clone(&cache)),
        ServerConfig {
            workers: WORKERS,
            queue_cap: 4 * total.max(1),
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .expect("server start");
    for k in 1..models {
        server
            .register_model(&format!("m{k}"), demo_factory(k as u64, Arc::clone(&cache)))
            .expect("register model");
    }
    let ids: Vec<String> = (0..models).map(|k| format!("m{k}")).collect();

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let server = server.clone();
            let ids = &ids;
            scope.spawn(move || {
                let mut data = CifarLike::new(server.in_dim, server.classes, 100 + c as u64);
                for r in 0..total / CLIENTS {
                    let b = data.test_batch(1);
                    let id = &ids[(c + r) % ids.len()];
                    let logits = server
                        .infer_with(b.x, SubmitOptions::default().with_model(id.clone()))
                        .expect("infer");
                    assert_eq!(logits.len(), server.classes);
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let (requests, batches) = server.counters();
    let stats = server.latency_stats().expect("latency samples");
    let (cache_hits, cache_builds) = cache.stats();
    let structures = cache.structures().len();
    // The registry acceptance invariant, asserted on every bench run: one
    // hidden structure per model plus the shared dense classifier.
    assert_eq!(
        structures,
        models + 1,
        "distinct structures: one hidden layer per model + shared classifier"
    );
    assert_eq!(
        cache_builds, structures,
        "plan builds must equal structures, not models × workers"
    );
    server.shutdown();
    Row {
        models,
        requests,
        batches,
        wall_s,
        throughput_rps: requests as f64 / wall_s.max(1e-9),
        p50_ms: stats.p50 * 1e3,
        p95_ms: stats.p95 * 1e3,
        p99_ms: stats.p99 * 1e3,
        occupancy: stats.occupancy,
        cache_builds,
        cache_hits,
        structures,
    }
}

/// One skewed-queue measurement: `hot_depth` hot entries queued in front
/// of `cold_pops` cold entries, then every cold entry popped through the
/// model-filtered path. Returns nanoseconds per cold pop.
fn bench_skewed_queue(hot_depth: usize, cold_pops: usize) -> f64 {
    let q = RequestQueue::new(hot_depth + cold_pops, Some(Duration::from_secs(3600)));
    let mut rxs = Vec::with_capacity(hot_depth + cold_pops);
    let mut push = |model: &str, id: usize| {
        let (tx, rx) = mpsc::channel();
        q.push(
            QueuedRequest {
                x: vec![id as f32],
                enqueued: Instant::now(),
                deadline: None,
                respond: tx,
                claim: ModelClaim::detached(model, BATCH, 1, 1),
                route: None,
            },
            Priority::Normal,
            None,
        )
        .expect("bench queue sized for every push");
        rxs.push(rx);
    };
    for i in 0..hot_depth {
        push("hot", i);
    }
    // Cold entries arrive *behind* the hot backlog: a class-FIFO scan
    // would walk the full hot depth for every one of these pops.
    for i in 0..cold_pops {
        push("cold", hot_depth + i);
    }
    let t0 = Instant::now();
    for _ in 0..cold_pops {
        let r = q
            .pop_model_until("cold", Instant::now() + Duration::from_millis(100))
            .expect("cold backlog is non-empty");
        assert_eq!(r.claim.id(), "cold");
    }
    let per_pop_ns = t0.elapsed().as_nanos() as f64 / cold_pops as f64;
    assert_eq!(q.model_backlog("cold"), 0);
    assert_eq!(q.model_backlog("hot"), hot_depth);
    q.check_invariants();
    per_pop_ns
}

struct SkewServingRow {
    hot_requests: usize,
    cold_requests: usize,
    cold_p50_ms: f64,
    cold_p95_ms: f64,
    steals: usize,
    quota_rejected: usize,
    occupancy: f64,
}

/// Serving under ~8:1 hot/cold skew with a fair-share quota on the hot
/// model: cold latency, steals and quota rejections are the trajectory.
fn run_skew_serving(hot_total: usize) -> SkewServingRow {
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "hot",
        demo_factory(0, Arc::clone(&cache)),
        ServerConfig {
            workers: WORKERS,
            queue_cap: 64,
            max_wait: Duration::from_millis(2),
            model_quota: ModelQuota::FairShare(0.5),
            ..ServerConfig::default()
        },
    )
    .expect("server start");
    server
        .register_model_with_quota("cold", ModelQuota::Unlimited, demo_factory(1, Arc::clone(&cache)))
        .expect("register cold model");

    let hot_clients = CLIENTS - 1;
    // What the closed-loop clients actually send (integer division), not
    // the offered figure — the trajectory must record reality.
    let hot_sent = hot_clients * (hot_total / hot_clients);
    let cold_total = (hot_total / 8).max(8);
    let mut cold_lat_ms: Vec<f64> = Vec::with_capacity(cold_total);
    std::thread::scope(|scope| {
        for c in 0..hot_clients {
            let server = server.clone();
            scope.spawn(move || {
                let mut data = CifarLike::new(server.in_dim, server.classes, 300 + c as u64);
                let mut sent = 0usize;
                while sent < hot_total / hot_clients {
                    let b = data.test_batch(1);
                    match server.infer_with(b.x, SubmitOptions::default().with_model("hot")) {
                        Ok(logits) => {
                            assert_eq!(logits.len(), server.classes);
                            sent += 1;
                        }
                        // Admission backpressure is the quota working as
                        // intended under skew: back off and retry.
                        Err(ServeError::ModelQuotaExceeded { .. })
                        | Err(ServeError::QueueFull { .. }) => std::thread::yield_now(),
                        Err(e) => panic!("hot client failed: {e}"),
                    }
                }
            });
        }
        // One cold client trickles requests through the same pool and
        // records its own latencies.
        let server_cold = server.clone();
        let cold_lat_ms = &mut cold_lat_ms;
        scope.spawn(move || {
            let mut data = CifarLike::new(server_cold.in_dim, server_cold.classes, 999);
            for _ in 0..cold_total {
                let b = data.test_batch(1);
                let t0 = Instant::now();
                let logits = server_cold
                    .infer_with(b.x, SubmitOptions::default().with_model("cold"))
                    .expect("cold traffic must never be starved or rejected");
                assert_eq!(logits.len(), server_cold.classes);
                cold_lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                std::thread::sleep(Duration::from_millis(1));
            }
        });
    });

    cold_lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| cold_lat_ms[((p / 100.0 * (cold_lat_ms.len() - 1) as f64) as usize).min(cold_lat_ms.len() - 1)];
    let stats = server.latency_stats().expect("latency samples");
    let row = SkewServingRow {
        hot_requests: hot_sent,
        cold_requests: cold_lat_ms.len(),
        cold_p50_ms: pct(50.0),
        cold_p95_ms: pct(95.0),
        steals: server.steals(),
        quota_rejected: server.rejected_quota(),
        occupancy: stats.occupancy,
    };
    server.shutdown();
    row
}

fn main() {
    let fast = std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let total = if fast { 256 } else { 4096 };
    println!(
        "registry bench — RBGP4 demo models, batch {BATCH}, {WORKERS} workers, \
         {CLIENTS} closed-loop clients, {total} requests per model count\n"
    );

    let mut rows = Vec::new();
    for models in [1usize, 2, 4] {
        let row = run_load(models, total);
        row.print();
        rows.push(row);
    }

    // Skewed-queue acceptance: per-pop cost for a cold model must be
    // independent of how deep the hot model has piled the shared queue.
    let (shallow_depth, deep_depth, cold_pops) =
        if fast { (256, 2048, 64) } else { (512, 8192, 64) };
    let shallow_ns = bench_skewed_queue(shallow_depth, cold_pops);
    let deep_ns = bench_skewed_queue(deep_depth, cold_pops);
    let ratio = deep_ns / shallow_ns.max(1e-9);
    let depth_ratio = deep_depth as f64 / shallow_depth as f64;
    println!(
        "\nskewed queue: cold pop behind {shallow_depth}-deep hot backlog {shallow_ns:>8.0} ns, \
         behind {deep_depth}-deep {deep_ns:>8.0} ns (ratio {ratio:.2}, depth ratio {depth_ratio:.0})"
    );
    // Threshold well below the depth ratio: an O(depth) scan approaches
    // `depth_ratio` (it can never *reach* it with a constant term, so a
    // threshold equal to it would be vacuous), while the index keeps the
    // ratio near 1 — depth_ratio/2 separates the two regimes in both the
    // fast and full profiles.
    assert!(
        ratio < depth_ratio / 2.0,
        "cold pops scale with hot queue depth (ratio {ratio:.2} vs depth ratio \
         {depth_ratio:.0}): the per-model index is not O(popped) anymore"
    );

    let skew_total = if fast { 192 } else { 2048 };
    let skew = run_skew_serving(skew_total);
    println!(
        "skewed serving: {} hot + {} cold requests — cold p50 {:.3} ms p95 {:.3} ms, \
         {} steals, {} quota rejections, occupancy {:.1}%",
        skew.hot_requests,
        skew.cold_requests,
        skew.cold_p50_ms,
        skew.cold_p95_ms,
        skew.steals,
        skew.quota_rejected,
        skew.occupancy * 100.0
    );

    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("batch", BATCH)
        .set("classes", CLASSES)
        .set("workers", WORKERS)
        .set("clients", CLIENTS)
        .set("requests_per_point", total)
        .set("fast_mode", fast);
    let mut skew_queue = Json::obj();
    skew_queue
        .set("cold_pops", cold_pops)
        .set("shallow_depth", shallow_depth)
        .set("shallow_per_pop_ns", shallow_ns)
        .set("deep_depth", deep_depth)
        .set("deep_per_pop_ns", deep_ns)
        .set("deep_vs_shallow_ratio", ratio);
    let mut skew_serving = Json::obj();
    skew_serving
        .set("hot_requests", skew.hot_requests)
        .set("cold_requests", skew.cold_requests)
        .set("cold_p50_ms", skew.cold_p50_ms)
        .set("cold_p95_ms", skew.cold_p95_ms)
        .set("steals", skew.steals)
        .set("quota_rejected", skew.quota_rejected)
        .set("occupancy", skew.occupancy);
    let mut skew_doc = Json::obj();
    skew_doc.set("queue", skew_queue).set("serving", skew_serving);
    doc.set("bench", "registry_bench")
        .set("config", meta)
        .set(
            "rows",
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        )
        .set("skew", skew_doc);
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH} ({} rows + skew)", rows.len()),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
