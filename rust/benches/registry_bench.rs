//! Multi-model serving throughput vs co-resident model count at fixed
//! offered load — the registry trajectory for the multi-tenant
//! `InferenceServer`.
//!
//! For each model count (1 / 2 / 4) the harness starts one worker pool,
//! registers that many RBGP4 demo models (distinct seeds → distinct
//! hidden-layer structures; the dense classifier structure is shared by
//! all), drives a fixed closed-loop load round-robining across the
//! models, and reports wall time, throughput, latency percentiles and —
//! the paper's amortization claim at the serving layer — plan-cache
//! builds, which must equal the number of **distinct structures**
//! (`models + 1`), not models × workers × layers.
//!
//! Results are written to `BENCH_registry.json` (in the cargo package
//! root, where `cargo bench` runs) so future multi-tenant PRs — cache
//! sharding, per-model admission control, NUMA-aware placement — can diff
//! against this trajectory the same way serving PRs diff against
//! `BENCH_server.json`.
//!
//! `cargo bench --bench registry_bench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::coordinator::{
    BatchModel, InferenceServer, NativeSparseModel, ServerConfig, SubmitOptions,
};
use rbgp::data::CifarLike;
use rbgp::kernels::PlanCache;
use rbgp::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

const OUT_PATH: &str = "BENCH_registry.json";
const CLIENTS: usize = 8;
const WORKERS: usize = 2;
const BATCH: usize = 16;
const CLASSES: usize = 16;

struct Row {
    models: usize,
    requests: usize,
    batches: usize,
    wall_s: f64,
    throughput_rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    occupancy: f64,
    cache_builds: usize,
    cache_hits: usize,
    structures: usize,
}

impl Row {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("models", self.models)
            .set("workers", WORKERS)
            .set("clients", CLIENTS)
            .set("batch", BATCH)
            .set("requests", self.requests)
            .set("batches", self.batches)
            .set("wall_s", self.wall_s)
            .set("throughput_rps", self.throughput_rps)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms)
            .set("p99_ms", self.p99_ms)
            .set("occupancy", self.occupancy)
            .set("cache_builds", self.cache_builds)
            .set("cache_hits", self.cache_hits)
            .set("structures", self.structures);
        j
    }

    fn print(&self) {
        println!(
            "models={:<2} {:>6} reqs in {:>5} batches  {:>8.1} req/s   \
             p50 {:>7.3} ms  p95 {:>7.3} ms  p99 {:>7.3} ms   occ {:>5.1}%   \
             {} builds for {} structures ({} hits)",
            self.models,
            self.requests,
            self.batches,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.occupancy * 100.0,
            self.cache_builds,
            self.structures,
            self.cache_hits,
        );
    }
}

fn demo_factory(
    seed: u64,
    cache: Arc<PlanCache>,
) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
    move || {
        let mut m = NativeSparseModel::rbgp4_demo(CLASSES, BATCH, 1, seed, Arc::clone(&cache))?;
        m.warm()?;
        Ok(Box::new(m) as Box<dyn BatchModel>)
    }
}

fn run_load(models: usize, total: usize) -> Row {
    // One shared cache for the whole pool *and* every model: each model's
    // hidden structure is derived once, the dense classifier once ever.
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "m0",
        demo_factory(0, Arc::clone(&cache)),
        ServerConfig {
            workers: WORKERS,
            queue_cap: 4 * total.max(1),
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .expect("server start");
    for k in 1..models {
        server
            .register_model(&format!("m{k}"), demo_factory(k as u64, Arc::clone(&cache)))
            .expect("register model");
    }
    let ids: Vec<String> = (0..models).map(|k| format!("m{k}")).collect();

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let server = server.clone();
            let ids = &ids;
            scope.spawn(move || {
                let mut data = CifarLike::new(server.in_dim, server.classes, 100 + c as u64);
                for r in 0..total / CLIENTS {
                    let b = data.test_batch(1);
                    let id = &ids[(c + r) % ids.len()];
                    let logits = server
                        .infer_with(b.x, SubmitOptions::default().with_model(id.clone()))
                        .expect("infer");
                    assert_eq!(logits.len(), server.classes);
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let (requests, batches) = server.counters();
    let stats = server.latency_stats().expect("latency samples");
    let (cache_hits, cache_builds) = cache.stats();
    let structures = cache.structures().len();
    // The registry acceptance invariant, asserted on every bench run: one
    // hidden structure per model plus the shared dense classifier.
    assert_eq!(
        structures,
        models + 1,
        "distinct structures: one hidden layer per model + shared classifier"
    );
    assert_eq!(
        cache_builds, structures,
        "plan builds must equal structures, not models × workers"
    );
    server.shutdown();
    Row {
        models,
        requests,
        batches,
        wall_s,
        throughput_rps: requests as f64 / wall_s.max(1e-9),
        p50_ms: stats.p50 * 1e3,
        p95_ms: stats.p95 * 1e3,
        p99_ms: stats.p99 * 1e3,
        occupancy: stats.occupancy,
        cache_builds,
        cache_hits,
        structures,
    }
}

fn main() {
    let fast = std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let total = if fast { 256 } else { 4096 };
    println!(
        "registry bench — RBGP4 demo models, batch {BATCH}, {WORKERS} workers, \
         {CLIENTS} closed-loop clients, {total} requests per model count\n"
    );

    let mut rows = Vec::new();
    for models in [1usize, 2, 4] {
        let row = run_load(models, total);
        row.print();
        rows.push(row);
    }

    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("batch", BATCH)
        .set("classes", CLASSES)
        .set("workers", WORKERS)
        .set("clients", CLIENTS)
        .set("requests_per_point", total)
        .set("fast_mode", fast);
    doc.set("bench", "registry_bench").set("config", meta).set(
        "rows",
        Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
    );
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH} ({} rows)", rows.len()),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
