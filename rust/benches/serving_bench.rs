//! Serving throughput/latency vs worker count at fixed offered load — the
//! scale-out trajectory for the multi-worker `InferenceServer`.
//!
//! For each worker count (1 / 2 / 4) the harness starts a server over the
//! native RBGP4 demo model (all workers sharing one `PlanCache`), drives a
//! fixed closed-loop load (`CLIENTS` client threads, `total` requests in
//! all), and reports wall time, throughput, latency percentiles, batch
//! occupancy and plan-cache traffic.
//!
//! Results are written to `BENCH_server.json` (in the cargo package root,
//! where `cargo bench` runs) so future serving PRs — NUMA-sharded
//! `BatchModel`, cache sharding, smarter batching — can diff against this
//! trajectory the same way kernel PRs diff against `BENCH_kernels.json`.
//!
//! `cargo bench --bench serving_bench` (RBGP_BENCH_FAST=1 quick pass)

use rbgp::coordinator::{BatchModel, InferenceServer, NativeSparseModel, ServerConfig};
use rbgp::data::CifarLike;
use rbgp::kernels::PlanCache;
use rbgp::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

const OUT_PATH: &str = "BENCH_server.json";
const CLIENTS: usize = 8;
const BATCH: usize = 16;
const CLASSES: usize = 16;
const SEED: u64 = 7;

struct Row {
    workers: usize,
    requests: usize,
    batches: usize,
    wall_s: f64,
    throughput_rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    occupancy: f64,
    cache_hits: usize,
    cache_misses: usize,
}

impl Row {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("workers", self.workers)
            .set("clients", CLIENTS)
            .set("batch", BATCH)
            .set("requests", self.requests)
            .set("batches", self.batches)
            .set("wall_s", self.wall_s)
            .set("throughput_rps", self.throughput_rps)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms)
            .set("p99_ms", self.p99_ms)
            .set("occupancy", self.occupancy)
            .set("cache_hits", self.cache_hits)
            .set("cache_misses", self.cache_misses);
        j
    }

    fn print(&self) {
        println!(
            "workers={:<2} {:>6} reqs in {:>5} batches  {:>8.1} req/s   \
             p50 {:>7.3} ms  p95 {:>7.3} ms  p99 {:>7.3} ms   occ {:>5.1}%   \
             cache {}h/{}m",
            self.workers,
            self.requests,
            self.batches,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.occupancy * 100.0,
            self.cache_hits,
            self.cache_misses,
        );
    }
}

fn run_load(workers: usize, total: usize) -> Row {
    // One shared cache per pool: structure is derived once (two plans),
    // every additional worker warms from cache.
    let cache = Arc::new(PlanCache::new());
    let model_cache = Arc::clone(&cache);
    let server = InferenceServer::start_model(
        move || {
            let mut m =
                NativeSparseModel::rbgp4_demo(CLASSES, BATCH, 1, SEED, Arc::clone(&model_cache))?;
            m.warm()?;
            Ok(Box::new(m) as Box<dyn BatchModel>)
        },
        ServerConfig {
            workers,
            queue_cap: 4 * total.max(1),
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .expect("server start");

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let server = server.clone();
            scope.spawn(move || {
                let mut data = CifarLike::new(server.in_dim, server.classes, 100 + c as u64);
                for _ in 0..total / CLIENTS {
                    let b = data.test_batch(1);
                    let logits = server.infer(b.x).expect("infer");
                    assert_eq!(logits.len(), server.classes);
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let (requests, batches) = server.counters();
    let stats = server.latency_stats().expect("latency samples");
    let (cache_hits, cache_misses) = cache.stats();
    server.shutdown();
    Row {
        workers,
        requests,
        batches,
        wall_s,
        throughput_rps: requests as f64 / wall_s.max(1e-9),
        p50_ms: stats.p50 * 1e3,
        p95_ms: stats.p95 * 1e3,
        p99_ms: stats.p99 * 1e3,
        occupancy: stats.occupancy,
        cache_hits,
        cache_misses,
    }
}

fn main() {
    let fast = std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let total = if fast { 256 } else { 4096 };
    println!(
        "serving bench — RBGP4 demo model, batch {BATCH}, {CLIENTS} closed-loop clients, \
         {total} requests per worker count\n"
    );

    let mut rows = Vec::new();
    for workers in [1usize, 2, 4] {
        let row = run_load(workers, total);
        row.print();
        rows.push(row);
    }

    let mut doc = Json::obj();
    let mut meta = Json::obj();
    meta.set("batch", BATCH)
        .set("classes", CLASSES)
        .set("clients", CLIENTS)
        .set("requests_per_point", total)
        .set("seed", SEED)
        .set("fast_mode", fast);
    doc.set("bench", "serving_bench").set("config", meta).set(
        "rows",
        Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
    );
    match std::fs::write(OUT_PATH, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH} ({} rows)", rows.len()),
        Err(e) => eprintln!("could not write {OUT_PATH}: {e}"),
    }
}
