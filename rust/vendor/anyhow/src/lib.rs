//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! implements the subset of `anyhow` the repository uses: [`Error`],
//! [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros. Semantics
//! match upstream for that subset:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//!   via `?` (blanket `From`, which is why [`Error`] itself deliberately
//!   does *not* implement `std::error::Error`);
//! * `{:#}` formatting prints the full source chain (`a: b: c`).

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional source chain.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Wrap any displayable message as an error.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// Create from a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error(Box::new(error))
    }

    /// Iterate the source chain starting at this error's root cause side.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.0.source()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        if f.alternate() {
            let mut source = self.0.source();
            while let Some(cause) = source {
                write!(f, ": {cause}")?;
                source = cause.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error(Box::new(error))
    }
}

/// Message-only error (what `anyhow!("...")` produces).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn message_and_ensure() {
        assert_eq!(fails(false).unwrap(), 7);
        let e = fails(true).unwrap_err();
        assert_eq!(format!("{e}"), "flag was true");
    }

    #[test]
    fn std_errors_convert_and_chain_prints() {
        fn open() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        let e = open().unwrap_err();
        assert!(!format!("{e:#}").is_empty());
    }

    #[test]
    fn expr_form_accepts_strings() {
        let msg = String::from("already formatted");
        let e: Error = anyhow!(msg.clone());
        assert_eq!(format!("{e}"), "already formatted");
    }
}
