//! Offline stub of the `xla` PJRT binding.
//!
//! The production deployment links a real PJRT CPU/GPU client; this vendored
//! stub provides the same API surface used by `rbgp::runtime::executor` so
//! the `xla` feature still type-checks in environments without the XLA
//! toolchain. Every entry point that would touch PJRT returns an error
//! explaining how to enable the real runtime (replace this crate in
//! `rust/vendor/xla` with the actual binding; the API is call-compatible).

use std::fmt;

const STUB_MSG: &str =
    "xla stub: PJRT runtime not available in this build (replace rust/vendor/xla with a real \
     PJRT binding to execute artifacts)";

/// Error type mirroring the binding's error enum.
pub struct Error(String);

impl Error {
    fn stub() -> Error {
        Error(STUB_MSG.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (flat f32 buffer in the real binding; opaque here).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub())
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// Compiled executable bound to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_runtime() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("PJRT runtime not available"));
    }
}
