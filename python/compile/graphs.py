"""Ramanujan bipartite graph generation — build-time Python mirror.

The Rust substrate (`rust/src/graph/`) is the production implementation;
this module mirrors the same constructions (2-lifts of complete bipartite
graphs, rejection sampling on the Ramanujan bound, RBGP4 mask layout) so
that

* `aot.py` can bake a mask's structure into AOT artifacts without a Rust
  round-trip, and
* pytest can cross-check the Pallas kernel against masks with the exact
  compact layout the Rust side produces (ascending-column order per row).

Masks serialize to the same JSON schema `rust/src/sparsity/rbgp4.rs` uses,
so either side can generate and the other consume.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass

import numpy as np

__all__ = [
    "GraphSpec",
    "Rbgp4Config",
    "Rbgp4Mask",
    "lift2",
    "sparse_biregular_by_lifts",
    "ramanujan_bound",
    "is_ramanujan",
    "generate_ramanujan",
]


def lift2(adj: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """One random 2-lift of a biregular bipartite graph.

    `adj` is (nu, dl) int — sorted adjacency rows. Returns (2nu, dl).
    Edge (u, v) keeps {(u,v),(u',v')} or crosses to {(u,v'),(u',v)} i.i.d.
    """
    nu, dl = adj.shape
    nv = int(adj.max()) + 1 if adj.size else 0
    cross = rng.integers(0, 2, size=adj.shape, dtype=np.int64).astype(bool)
    top = np.where(cross, adj + nv, adj)
    bot = np.where(cross, adj, adj + nv)
    out = np.concatenate([top, bot], axis=0)
    return np.sort(out, axis=1)


def lifts_for_sparsity(sp: float) -> int:
    """Number of 2-lifts for dyadic sparsity sp = 1 - 2^-k."""
    if not 0.0 <= sp < 1.0:
        raise ValueError(f"sparsity {sp} out of [0,1)")
    k = round(math.log2(1.0 / (1.0 - sp)))
    if abs((1.0 - 0.5**k) - sp) > 1e-9:
        raise ValueError(f"sparsity {sp} is not dyadic (1 - 2^-k)")
    return k


def sparse_biregular_by_lifts(m: int, n: int, sp: float, rng: np.random.Generator) -> np.ndarray:
    """(m × n) biregular graph of dyadic sparsity sp via repeated 2-lifts
    of the complete bipartite graph (paper Appendix 8.1). Returns sorted
    adjacency (m, dl) with dl = (1-sp)·n."""
    k = lifts_for_sparsity(sp)
    frac = 0.5**k
    bm, bn = round(m * frac), round(n * frac)
    if bm << k != m or bn << k != n:
        raise ValueError(f"{m}x{n} not divisible by 2^{k} for sparsity {sp}")
    if bm < 1 or bn < 1:
        raise ValueError(f"sparsity {sp} too high for {m}x{n}")
    adj = np.tile(np.arange(bn, dtype=np.int64), (bm, 1))
    for _ in range(k):
        adj = lift2(adj, rng)
    return adj


def ramanujan_bound(dl: int, dr: int) -> float:
    return math.sqrt(max(dl - 1, 0)) + math.sqrt(max(dr - 1, 0))


def _second_singular(adj: np.ndarray, nv: int) -> float:
    nu, dl = adj.shape
    ba = np.zeros((nu, nv), dtype=np.float64)
    ba[np.arange(nu)[:, None], adj] = 1.0
    s = np.linalg.svd(ba, compute_uv=False)
    return float(s[1]) if len(s) > 1 else 0.0


def is_ramanujan(adj: np.ndarray, nv: int) -> bool:
    """Check λ₂ ≤ √(dl−1) + √(dr−1) for a biregular adjacency."""
    nu, dl = adj.shape
    dr = nu * dl // nv
    lam2 = _second_singular(adj, nv)
    return lam2 <= ramanujan_bound(dl, dr) + 1e-9


def generate_ramanujan(
    m: int, n: int, sp: float, rng: np.random.Generator, max_attempts: int = 64
) -> np.ndarray:
    """Rejection-sample 2-lift chains until Ramanujan; falls back to the
    best-λ₂ sample (still an expander) after `max_attempts`."""
    if sp == 0.0:
        return np.tile(np.arange(n, dtype=np.int64), (m, 1))
    best, best_lam = None, float("inf")
    for _ in range(max_attempts):
        adj = sparse_biregular_by_lifts(m, n, sp, rng)
        lam2 = _second_singular(adj, n)
        nu, dl = adj.shape
        if lam2 <= ramanujan_bound(dl, nu * dl // n) + 1e-9:
            return adj
        if lam2 < best_lam:
            best, best_lam = adj, lam2
    return best


@dataclass(frozen=True)
class GraphSpec:
    nu: int
    nv: int
    sp: float

    @property
    def dl(self) -> int:
        return round((1.0 - self.sp) * self.nv)


@dataclass(frozen=True)
class Rbgp4Config:
    """Mirror of rust Rbgp4Config: G = G_o ⊗ G_r ⊗ G_i ⊗ G_b."""

    go: GraphSpec
    gr: tuple[int, int]
    gi: GraphSpec
    gb: tuple[int, int]

    @property
    def rows(self) -> int:
        return self.go.nu * self.gr[0] * self.gi.nu * self.gb[0]

    @property
    def cols(self) -> int:
        return self.go.nv * self.gr[1] * self.gi.nv * self.gb[1]

    @property
    def tile_m(self) -> int:
        return self.gr[0] * self.gi.nu * self.gb[0]

    @property
    def tile_k(self) -> int:
        return self.gr[1] * self.gi.nv * self.gb[1]

    @property
    def d_o(self) -> int:
        return self.go.dl

    @property
    def d_i(self) -> int:
        return self.gi.dl

    @property
    def tile_row_nnz(self) -> int:
        return self.gr[1] * self.d_i * self.gb[1]

    @property
    def row_nnz(self) -> int:
        return self.d_o * self.tile_row_nnz

    @property
    def sparsity(self) -> float:
        return 1.0 - (1.0 - self.go.sp) * (1.0 - self.gi.sp)

    def to_json_dict(self) -> dict:
        return {
            "go_nu": self.go.nu,
            "go_nv": self.go.nv,
            "go_sp": self.go.sp,
            "gr_nu": self.gr[0],
            "gr_nv": self.gr[1],
            "gi_nu": self.gi.nu,
            "gi_nv": self.gi.nv,
            "gi_sp": self.gi.sp,
            "gb_nu": self.gb[0],
            "gb_nv": self.gb[1],
        }

    @staticmethod
    def from_json_dict(d: dict) -> "Rbgp4Config":
        return Rbgp4Config(
            go=GraphSpec(int(d["go_nu"]), int(d["go_nv"]), float(d["go_sp"])),
            gr=(int(d["gr_nu"]), int(d["gr_nv"])),
            gi=GraphSpec(int(d["gi_nu"]), int(d["gi_nv"]), float(d["gi_sp"])),
            gb=(int(d["gb_nu"]), int(d["gb_nv"])),
        )


@dataclass
class Rbgp4Mask:
    """A sampled RBGP4 mask: config + the two sparse base adjacencies.

    Layout contract (identical to rust `Rbgp4Mask`):
      row u = ((u_o·MR + u_r)·MI + u_i)·MB + u_b
      non-zeros of row u, ascending column order, are
      {((adj_o[u_o,ko]·NR + vr)·NI + adj_i[u_i,ki])·NB + vb}
      iterated lexicographically over (ko, vr, ki, vb).
    """

    config: Rbgp4Config
    adj_o: np.ndarray  # (m_o, d_o) int, sorted rows
    adj_i: np.ndarray  # (m_i, d_i) int, sorted rows

    @staticmethod
    def sample(config: Rbgp4Config, seed: int) -> "Rbgp4Mask":
        rng = np.random.default_rng(seed)
        adj_o = generate_ramanujan(config.go.nu, config.go.nv, config.go.sp, rng)
        adj_i = generate_ramanujan(config.gi.nu, config.gi.nv, config.gi.sp, rng)
        return Rbgp4Mask(config, adj_o, adj_i)

    def local_cols(self) -> np.ndarray:
        """(m_i, tile_row_nnz) tile-local columns per u_i (ascending)."""
        c = self.config
        nr, ni, nb = c.gr[1], c.gi.nv, c.gb[1]
        vr = np.arange(nr)[:, None, None]
        vi = self.adj_i[:, None, :, None]  # (m_i, 1, d_i, 1)
        vb = np.arange(nb)[None, None, :]
        local = (vr * ni + vi) * nb + vb  # (m_i, nr, d_i, nb)
        return local.reshape(c.gi.nu, c.tile_row_nnz)

    def col_index(self) -> np.ndarray:
        """(rows, row_nnz) absolute column index of every stored non-zero."""
        c = self.config
        lc = self.local_cols()  # (m_i, trn)
        # Absolute col = adj_o[u_o, ko]*TK + local. Build per (u_o, u_i).
        tiles = self.adj_o * c.tile_k  # (m_o, d_o) base offsets
        # (m_o, m_i, d_o, trn)
        cols = tiles[:, None, :, None] + lc[None, :, None, :]
        cols = cols.reshape(c.go.nu, c.gi.nu, c.row_nnz)
        # Expand to full row order (u_o, u_r, u_i, u_b).
        cols = np.broadcast_to(
            cols[:, None, :, None, :],
            (c.go.nu, c.gr[0], c.gi.nu, c.gb[0], c.row_nnz),
        )
        return cols.reshape(c.rows, c.row_nnz).astype(np.int32)

    def dense(self) -> np.ndarray:
        """Dense 0/1 mask (rows × cols)."""
        c = self.config
        m = np.zeros((c.rows, c.cols), dtype=np.float32)
        cols = self.col_index()
        m[np.arange(c.rows)[:, None], cols] = 1.0
        return m

    def to_json(self) -> str:
        return json.dumps(
            {
                "config": self.config.to_json_dict(),
                "adj_o": [int(x) for x in self.adj_o.reshape(-1)],
                "adj_i": [int(x) for x in self.adj_i.reshape(-1)],
            }
        )

    @staticmethod
    def from_json(text: str) -> "Rbgp4Mask":
        d = json.loads(text)
        config = Rbgp4Config.from_json_dict(d["config"])
        adj_o = np.array(d["adj_o"], dtype=np.int64).reshape(config.go.nu, config.d_o)
        adj_i = np.array(d["adj_i"], dtype=np.int64).reshape(config.gi.nu, config.d_i)
        return Rbgp4Mask(config, adj_o, adj_i)
