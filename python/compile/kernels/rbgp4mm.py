"""L1 — Pallas RBGP4MM kernel.

`O = W_s · I` with `W_s` in RBGP4 compact storage, as a Pallas kernel whose
grid/BlockSpec structure is the TPU adaptation of the paper's Algorithm 1
(DESIGN.md §Hardware-Adaptation):

* grid = (m_o, N/TN, d_o): one (TM × TN) output block per (u_o, jn) —
  the "thread block" — stepped d_o times — the `G_o`-skipped steps. Zero
  tiles of `W_s` are *never* visited: the step axis enumerates non-zero
  tiles only.
* `I` block index_map reads the scalar-prefetched `adj_o` to gather the
  right (TK × TN) input tile per step — the HBM→VMEM analogue of Figure 1's
  DRAM→shared-memory tile load.
* inside the kernel one einsum contracts the compact (MR·MI·MB × trn)
  weight block against the `adj_i`-gathered rows of the input tile: the MXU
  sees a dense batched matmul; row repetition (`G_r`, `G_b`) appears as the
  MR·MB batch dimensions reusing each gathered row — the register-reuse
  analogue.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU efficiency is estimated from the VMEM footprint
(see `vmem_footprint`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..graphs import Rbgp4Config, Rbgp4Mask

__all__ = ["rbgp4mm_pallas", "make_rbgp4mm", "vmem_footprint"]


def _kernel(adj_ref, data_ref, lc_ref, i_ref, o_ref, *, c: Rbgp4Config, tn: int):
    """One (u_o, jn, ko) grid step: accumulate a packed step into o_ref."""
    del adj_ref  # consumed by the index_maps, not the body
    ko = pl.program_id(2)

    @pl.when(ko == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mr, mi, mb = c.gr[0], c.gi.nu, c.gb[0]
    trn = c.tile_row_nnz
    wk = data_ref[...]  # (TM, trn) — this step's compact panel
    itile = i_ref[...]  # (TK, TN) — the adj_o-gathered input tile
    lc = lc_ref[...]  # (m_i, trn) — intra-tile gather pattern
    # adj_i gather: (m_i, trn, TN) rows of the input tile.
    gathered = itile[lc.reshape(-1), :].reshape(mi, trn, tn)
    # Compact weights in (u_r, u_i, u_b) row order -> batch by u_i.
    w4 = wk.reshape(mr, mi, mb, trn).transpose(1, 0, 2, 3)  # (mi, mr, mb, trn)
    part = jnp.einsum(
        "mrbt,mtn->mrbn", w4, gathered, preferred_element_type=o_ref.dtype
    )
    o_ref[...] += part.transpose(1, 0, 2, 3).reshape(c.tile_m, tn)


def _pick_tn(n: int) -> int:
    """Largest power-of-two divisor of n, capped at 256."""
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return n


@functools.partial(jax.jit, static_argnames=("config", "tn"))
def rbgp4mm_pallas(
    data: jnp.ndarray,
    i: jnp.ndarray,
    adj_o: jnp.ndarray,
    local_cols: jnp.ndarray,
    config: Rbgp4Config,
    tn: int | None = None,
) -> jnp.ndarray:
    """RBGP4MM via Pallas (interpret mode).

    data:       (rows, row_nnz) f32 compact weights
    i:          (K, N) f32, N divisible by the chosen TN
    adj_o:      (m_o·d_o,) i32 flattened tile adjacency (scalar-prefetch)
    local_cols: (m_i, trn) i32
    """
    c = config
    rows, k, n = c.rows, c.cols, i.shape[1]
    assert data.shape == (rows, c.row_nnz), data.shape
    assert i.shape[0] == k, (i.shape, k)
    tn = tn or _pick_tn(n)
    assert n % tn == 0, (n, tn)
    trn, tm, tk = c.tile_row_nnz, c.tile_m, c.tile_k
    grid = (c.go.nu, n // tn, c.d_o)

    kernel = functools.partial(_kernel, c=c, tn=tn)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # Compact weight panel for (u_o, step ko).
                pl.BlockSpec((tm, trn), lambda uo, jn, ko, adj: (uo, ko)),
                # Intra-tile gather pattern: whole array each step.
                pl.BlockSpec(
                    (c.gi.nu, trn), lambda uo, jn, ko, adj: (0, 0)
                ),
                # Input tile: row index comes from the prefetched adjacency.
                pl.BlockSpec(
                    (tk, tn), lambda uo, jn, ko, adj: (adj[uo * c.d_o + ko], jn)
                ),
            ],
            out_specs=pl.BlockSpec((tm, tn), lambda uo, jn, ko, adj: (uo, jn)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, n), data.dtype),
        interpret=True,
    )(adj_o, data, local_cols, i)


def make_rbgp4mm(mask: Rbgp4Mask, tn: int | None = None):
    """Close over a mask's static index arrays; returns f(data, i) -> O."""
    adj_o = jnp.asarray(mask.adj_o.reshape(-1), dtype=jnp.int32)
    lc = jnp.asarray(mask.local_cols(), dtype=jnp.int32)

    def f(data: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
        return rbgp4mm_pallas(data, i, adj_o, lc, mask.config, tn)

    return f


def vmem_footprint(config: Rbgp4Config, tn: int, dtype_bytes: int = 4) -> dict:
    """Estimated VMEM bytes per grid step and MXU utilization proxy.

    Used by the perf pass (EXPERIMENTS.md §Perf) — interpret-mode wallclock
    is *not* a TPU proxy, but the VMEM working set and the matmul shapes
    feeding the MXU are compile-time facts of the BlockSpec choice.
    """
    c = config
    w_block = c.tile_m * c.tile_row_nnz * dtype_bytes
    i_block = c.tile_k * tn * dtype_bytes
    o_block = c.tile_m * tn * dtype_bytes
    lc_block = c.gi.nu * c.tile_row_nnz * 4
    gathered = c.gi.nu * c.tile_row_nnz * tn * dtype_bytes
    total = w_block + i_block + o_block + lc_block + gathered
    # MXU proxy: the einsum is m_i batched (MR·MB × trn)·(trn × TN) matmuls;
    # utilization of a 128×128 systolic array is limited by the smaller of
    # the row-group and trn dimensions.
    rows_per_mm = c.gr[0] * c.gb[0]
    mxu_util = min(rows_per_mm, 128) / 128 * min(c.tile_row_nnz, 128) / 128
    return {
        "w_block_bytes": w_block,
        "i_block_bytes": i_block,
        "o_block_bytes": o_block,
        "gathered_bytes": gathered,
        "total_bytes": total,
        "fits_16mib_vmem": total <= 16 * 1024 * 1024,
        "matmul_shape": (rows_per_mm, c.tile_row_nnz, tn),
        "mxu_batch": c.gi.nu,
        "mxu_util_proxy": mxu_util,
    }
