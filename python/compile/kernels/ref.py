"""Pure-jnp correctness oracles for RBGP4MM.

Two references:

* `rbgp4mm_dense_ref` — scatter compact storage to a dense (M, K) matrix and
  matmul: the gold standard the Pallas kernel (and the Rust kernels) are
  checked against.
* `rbgp4mm_gather_ref` — the differentiable gather-einsum formulation used
  by the L2 model's training path (autodiff-friendly, no pallas_call).

Both consume the compact contract format (data, adj_o, adj_i) defined in
`graphs.Rbgp4Mask` / rust `sparsity::rbgp4`.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..graphs import Rbgp4Config, Rbgp4Mask


def expand_dense(data: jnp.ndarray, col_index: np.ndarray, cols: int) -> jnp.ndarray:
    """Scatter compact (rows, row_nnz) data into a dense (rows, cols) W."""
    rows, _row_nnz = data.shape
    w = jnp.zeros((rows, cols), dtype=data.dtype)
    return w.at[jnp.arange(rows)[:, None], col_index].set(data)


def rbgp4mm_dense_ref(data: jnp.ndarray, mask: Rbgp4Mask, i: jnp.ndarray) -> jnp.ndarray:
    """O = W_s · I by explicit dense expansion (oracle)."""
    w = expand_dense(data, mask.col_index(), mask.config.cols)
    return w @ i


def rbgp4mm_gather_ref(
    data: jnp.ndarray,
    i: jnp.ndarray,
    adj_o: jnp.ndarray,
    local_cols: jnp.ndarray,
    config: Rbgp4Config,
) -> jnp.ndarray:
    """Differentiable gather-einsum RBGP4MM.

    data:       (rows, row_nnz) compact weights
    i:          (K, N) dense input
    adj_o:      (m_o, d_o) int32 tile adjacency
    local_cols: (m_i, trn) int32 intra-tile columns
    Returns O:  (rows, N)

    Per output-tile row u_o and step ko, the touched I rows are
    `adj_o[u_o, ko]·TK + local_cols` — gathered once and contracted against
    the (MR, MI, MB, trn) view of the compact data, mirroring the tiled GPU
    schedule (and the Pallas kernel) exactly.
    """
    c = config
    n = i.shape[1]
    mo, mr, mi, mb = c.go.nu, c.gr[0], c.gi.nu, c.gb[0]
    trn, d_o = c.tile_row_nnz, c.d_o
    # Absolute gathered column index per (m_o, d_o, m_i, trn).
    cols = adj_o[:, :, None, None] * c.tile_k + local_cols[None, None, :, :]
    gathered = i[cols.reshape(-1), :].reshape(mo, d_o, mi, trn, n)
    # Compact data viewed as (m_o, MR, MI, MB, d_o, trn); bring m_i forward.
    w = data.reshape(mo, mr, mi, mb, d_o, trn)
    out = jnp.einsum("omrbkt,okmtn->omrbn", w.transpose(0, 2, 1, 3, 4, 5), gathered)
    # out: (m_o, m_i, MR, MB, n) -> row order (m_o, MR, m_i, MB).
    out = out.transpose(0, 2, 1, 3, 4)
    return out.reshape(c.rows, n)


def masked_dense_matmul(w_dense: jnp.ndarray, mask01: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
    """Baseline: (W ∘ mask) · I — what unstructured/block training computes."""
    return (w_dense * mask01) @ i
