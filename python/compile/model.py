"""L2 — JAX sparse model: forward/backward + SGD-momentum + distillation.

A multi-layer perceptron whose hidden layers carry RBGP4 masks (the paper's
predefined-sparsity setup applied to the CIFAR-like task). Activations are
kept feature-major `(features, batch)` so every sparse layer is literally
the paper's SDMM `O = W_s · I`.

Two forward paths over the *same* compact parameters:
* `forward` — differentiable gather-einsum (`ref.rbgp4mm_gather_ref`); used
  inside the AOT-exported train step.
* `forward_pallas` — the L1 Pallas kernel; used by the AOT-exported
  inference graph (and cross-checked against `forward` in pytest).

The train step implements the paper's §6 recipe at small scale: SGD with
momentum 0.9, weight decay 1e-4, and optional knowledge distillation from a
dense teacher's logits (Hinton KD: soften both with temperature T).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import GraphSpec, Rbgp4Config, Rbgp4Mask
from .kernels.ref import rbgp4mm_gather_ref
from .kernels.rbgp4mm import make_rbgp4mm

__all__ = [
    "ModelSpec",
    "default_spec",
    "init_params",
    "forward",
    "forward_pallas",
    "loss_fn",
    "train_step",
    "sgd_hparams",
]


@dataclass(frozen=True)
class ModelSpec:
    """Static model description: input dim, sparse hidden layers, classes."""

    in_dim: int
    classes: int
    layer_configs: tuple[Rbgp4Config, ...]
    masks: tuple[Rbgp4Mask, ...] = field(default=(), compare=False)

    @property
    def hidden_dims(self) -> list[int]:
        return [c.rows for c in self.layer_configs]

    def validate(self) -> None:
        prev = self.in_dim
        for idx, c in enumerate(self.layer_configs):
            if c.cols != prev:
                raise ValueError(f"layer {idx}: cols {c.cols} != prev dim {prev}")
            prev = c.rows


def _lift_feasible(nu: int, nv: int, sp: float) -> bool:
    """Dyadic sparsity sp = 1 - 2^-k is reachable iff 2^k divides both sides."""
    import math

    if sp == 0.0:
        return True
    k = round(math.log2(1.0 / (1.0 - sp)))
    if abs((1.0 - 0.5**k) - sp) > 1e-9:
        return False
    return nu % (1 << k) == 0 and nv % (1 << k) == 0


def _layer_config(rows: int, cols: int, sp_o: float, sp_i: float) -> Rbgp4Config:
    """A reasonable RBGP4 factorization of a (rows × cols) layer:
    G_r=(·,1), G_b=(1,1) gives row repetition; G_i is the paper's Table-2
    intra-tile size (32×32 when it fits, smaller otherwise) and G_o absorbs
    the rest — the largest feasible split is chosen automatically."""
    for gi in (32, 16, 8, 4):
        for gr_u in (4, 2, 1):
            if rows % (gr_u * gi) or cols % gi:
                continue
            mo, no = rows // (gr_u * gi), cols // gi
            if not (_lift_feasible(mo, no, sp_o) and _lift_feasible(gi, gi, sp_i)):
                continue
            if round((1 - sp_o) * no) < 1 or round((1 - sp_i) * gi) < 1:
                continue
            return Rbgp4Config(
                go=GraphSpec(mo, no, sp_o),
                gr=(gr_u, 1),
                gi=GraphSpec(gi, gi, sp_i),
                gb=(1, 1),
            )
    raise ValueError(f"no feasible RBGP4 factorization for {rows}x{cols} sp=({sp_o},{sp_i})")


def default_spec(
    in_dim: int = 1024,
    hidden: tuple[int, ...] = (1024, 1024),
    classes: int = 10,
    sp_o: float = 0.5,
    sp_i: float = 0.5,
    seed: int = 0,
) -> ModelSpec:
    """The E2E driver's model: MLP 1024 → 1024 → 1024 → classes with two
    RBGP4 sparse layers at overall sparsity 1-(1-sp_o)(1-sp_i)."""
    cfgs = []
    prev = in_dim
    for h in hidden:
        cfgs.append(_layer_config(h, prev, sp_o, sp_i))
        prev = h
    masks = tuple(Rbgp4Mask.sample(c, seed + 101 * i) for i, c in enumerate(cfgs))
    spec = ModelSpec(in_dim=in_dim, classes=classes, layer_configs=tuple(cfgs), masks=masks)
    spec.validate()
    return spec


def init_params(spec: ModelSpec, seed: int = 0) -> dict:
    """He-init over non-zero fan-in for compact data; zero-init classifier
    bias. Returns a flat dict of named arrays (the AOT input order is the
    sorted key order — see aot.py)."""
    rng = np.random.default_rng(seed)
    params: dict[str, jnp.ndarray] = {}
    for idx, c in enumerate(spec.layer_configs):
        scale = np.sqrt(2.0 / c.row_nnz)
        params[f"w{idx}"] = jnp.asarray(
            rng.normal(size=(c.rows, c.row_nnz)).astype(np.float32) * scale
        )
    last = spec.layer_configs[-1].rows if spec.layer_configs else spec.in_dim
    params["wc"] = jnp.asarray(
        rng.normal(size=(spec.classes, last)).astype(np.float32) * np.sqrt(1.0 / last)
    )
    params["bc"] = jnp.zeros((spec.classes,), jnp.float32)
    return params


def _mask_arrays(mask: Rbgp4Mask) -> tuple[jnp.ndarray, jnp.ndarray]:
    return (
        jnp.asarray(mask.adj_o, dtype=jnp.int32),
        jnp.asarray(mask.local_cols(), dtype=jnp.int32),
    )


def forward(params: dict, x: jnp.ndarray, spec: ModelSpec) -> jnp.ndarray:
    """Differentiable forward. `x` is (batch, in_dim); returns (batch, classes)."""
    h = x.T  # feature-major: (features, batch)
    for idx, (cfg, mask) in enumerate(zip(spec.layer_configs, spec.masks)):
        adj_o, lc = _mask_arrays(mask)
        h = rbgp4mm_gather_ref(params[f"w{idx}"], h, adj_o, lc, cfg)
        h = jax.nn.relu(h)
    logits = params["wc"] @ h + params["bc"][:, None]
    return logits.T


def forward_pallas(params: dict, x: jnp.ndarray, spec: ModelSpec) -> jnp.ndarray:
    """Inference forward through the L1 Pallas kernel."""
    h = x.T
    for idx, mask in enumerate(spec.masks):
        f = make_rbgp4mm(mask)
        h = jax.nn.relu(f(params[f"w{idx}"], h))
    logits = params["wc"] @ h + params["bc"][:, None]
    return logits.T


def loss_fn(
    params: dict,
    x: jnp.ndarray,
    y: jnp.ndarray,
    spec: ModelSpec,
    teacher_logits: jnp.ndarray | None = None,
    kd_alpha: float = 0.3,
    kd_temp: float = 4.0,
) -> jnp.ndarray:
    """Cross-entropy (+ optional Hinton KD against dense-teacher logits)."""
    logits = forward(params, x, spec)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.sum(y * logp, axis=-1))
    if teacher_logits is None:
        return ce
    t = kd_temp
    p_teacher = jax.nn.softmax(teacher_logits / t, axis=-1)
    logp_student = jax.nn.log_softmax(logits / t, axis=-1)
    kd = -jnp.mean(jnp.sum(p_teacher * logp_student, axis=-1)) * (t * t)
    return (1.0 - kd_alpha) * ce + kd_alpha * kd


def sgd_hparams() -> dict:
    """The paper's §6 optimizer settings."""
    return {"momentum": 0.9, "weight_decay": 1e-4}


def train_step(
    params: dict,
    velocity: dict,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lr: jnp.ndarray,
    spec: ModelSpec,
    teacher_logits: jnp.ndarray | None = None,
) -> tuple[dict, dict, jnp.ndarray]:
    """One SGD-momentum step on the compact parameters.

    Because the mask is encoded in the *storage layout* (only non-zero
    weights exist as parameters), predefined sparsity is preserved by
    construction — no mask re-application after the update.
    """
    hp = sgd_hparams()
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y, spec, teacher_logits)
    new_p, new_v = {}, {}
    for k in params:
        g = grads[k] + hp["weight_decay"] * params[k]
        v = hp["momentum"] * velocity[k] + g
        new_v[k] = v
        new_p[k] = params[k] - lr * v
    return new_p, new_v, loss
