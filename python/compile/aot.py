"""AOT lowering: JAX graphs → HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Each artifact `<name>.hlo.txt` ships with `<name>.json` metadata describing
the exact positional input/output signature (names, shapes, dtypes), the
model spec, and the sampled masks — everything the Rust coordinator needs
to drive the executable without Python.

Artifacts (per model config):
  forward        — Pallas-kernel inference: (params..., x) -> logits
  train_step     — fused SGD-momentum step:
                   (params..., velocities..., x, y, lr) ->
                   (new_params..., new_velocities..., loss)
  train_step_kd  — same plus teacher_logits input (knowledge distillation)
  smoke          — tiny matmul+2 graph for runtime plumbing tests

Usage: python -m compile.aot --out ../artifacts [--batch 256] [--seed 0]
       [--sp-o 0.5] [--sp-i 0.5] [--hidden 1024,1024] [--in-dim 1024]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

__all__ = ["to_hlo_text", "export_artifacts"]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side can uniformly `to_tuple`)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _sig(named_arrays: list[tuple[str, jnp.ndarray]]) -> list[dict]:
    return [
        {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
        for n, a in named_arrays
    ]


def _write(out_dir: str, name: str, hlo: str, meta: dict) -> None:
    # Guard against XLA's default constant elision: without
    # print_large_constants=True, big literals (e.g. the baked adjacency
    # arrays) print as "...}" and the text parser silently materializes
    # garbage — the executable then runs but computes the wrong function.
    if "..." in hlo:
        raise RuntimeError(
            f"{name}: HLO text contains elided constants ('...'); "
            "as_hlo_text must be called with print_large_constants=True"
        )
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
    print(f"  wrote {name}.hlo.txt ({len(hlo)} chars)")


def _param_order(params: dict) -> list[str]:
    """Canonical positional order: sorted names (stable contract with Rust)."""
    return sorted(params.keys())


def export_artifacts(
    out_dir: str,
    batch: int = 256,
    in_dim: int = 1024,
    hidden: tuple[int, ...] = (1024, 1024),
    classes: int = 10,
    sp_o: float = 0.5,
    sp_i: float = 0.5,
    seed: int = 0,
) -> dict:
    """Lower and write every artifact; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    spec = M.default_spec(
        in_dim=in_dim, hidden=hidden, classes=classes, sp_o=sp_o, sp_i=sp_i, seed=seed
    )
    params = M.init_params(spec, seed)
    order = _param_order(params)
    pshapes = [(k, params[k]) for k in order]
    x = jnp.zeros((batch, in_dim), jnp.float32)
    y = jnp.zeros((batch, classes), jnp.float32)
    lr = jnp.zeros((), jnp.float32)

    common_meta = {
        "batch": batch,
        "in_dim": in_dim,
        "hidden": list(hidden),
        "classes": classes,
        "sp_o": sp_o,
        "sp_i": sp_i,
        "overall_sparsity": 1.0 - (1.0 - sp_o) * (1.0 - sp_i),
        "seed": seed,
        "param_order": order,
        "layer_configs": [c.to_json_dict() for c in spec.layer_configs],
        "masks": [json.loads(m.to_json()) for m in spec.masks],
    }

    # ---- forward (Pallas inference path) --------------------------------
    def fwd_flat(*args):
        ps = dict(zip(order, args[: len(order)]))
        xx = args[len(order)]
        return (M.forward_pallas(ps, xx, spec),)

    lowered = jax.jit(fwd_flat).lower(*[p for _, p in pshapes], x)
    _write(
        out_dir,
        "forward",
        to_hlo_text(lowered),
        {
            **common_meta,
            "kind": "forward",
            "inputs": _sig(pshapes + [("x", x)]),
            "outputs": [{"name": "logits", "shape": [batch, classes], "dtype": "float32"}],
        },
    )

    # ---- train_step (no KD) ---------------------------------------------
    def step_flat(*args):
        k = len(order)
        ps = dict(zip(order, args[:k]))
        vs = dict(zip(order, args[k : 2 * k]))
        xx, yy, lrr = args[2 * k], args[2 * k + 1], args[2 * k + 2]
        np_, nv_, loss = M.train_step(ps, vs, xx, yy, lrr, spec)
        return tuple(np_[n] for n in order) + tuple(nv_[n] for n in order) + (loss,)

    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    vshapes = [(f"v_{k}", vel[k]) for k in order]
    step_args = [p for _, p in pshapes] + [v for _, v in vshapes] + [x, y, lr]
    lowered = jax.jit(step_flat).lower(*step_args)
    _write(
        out_dir,
        "train_step",
        to_hlo_text(lowered),
        {
            **common_meta,
            "kind": "train_step",
            "inputs": _sig(pshapes + vshapes + [("x", x), ("y", y), ("lr", lr)]),
            "outputs": _sig(
                [(f"new_{k}", params[k]) for k in order]
                + [(f"new_v_{k}", vel[k]) for k in order]
                + [("loss", lr)]
            ),
        },
    )

    # ---- train_step_kd (teacher logits input) ---------------------------
    def step_kd_flat(*args):
        k = len(order)
        ps = dict(zip(order, args[:k]))
        vs = dict(zip(order, args[k : 2 * k]))
        xx, yy, tl, lrr = args[2 * k], args[2 * k + 1], args[2 * k + 2], args[2 * k + 3]
        np_, nv_, loss = M.train_step(ps, vs, xx, yy, lrr, spec, teacher_logits=tl)
        return tuple(np_[n] for n in order) + tuple(nv_[n] for n in order) + (loss,)

    tl = jnp.zeros((batch, classes), jnp.float32)
    kd_args = [p for _, p in pshapes] + [v for _, v in vshapes] + [x, y, tl, lr]
    lowered = jax.jit(step_kd_flat).lower(*kd_args)
    _write(
        out_dir,
        "train_step_kd",
        to_hlo_text(lowered),
        {
            **common_meta,
            "kind": "train_step_kd",
            "inputs": _sig(
                pshapes + vshapes + [("x", x), ("y", y), ("teacher_logits", tl), ("lr", lr)]
            ),
            "outputs": _sig(
                [(f"new_{k}", params[k]) for k in order]
                + [(f"new_v_{k}", vel[k]) for k in order]
                + [("loss", lr)]
            ),
        },
    )

    # ---- smoke (runtime plumbing test) ----------------------------------
    def smoke(a, b):
        return (jnp.matmul(a, b) + 2.0,)

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    _write(
        out_dir,
        "smoke",
        to_hlo_text(jax.jit(smoke).lower(s, s)),
        {
            "kind": "smoke",
            "inputs": [
                {"name": "a", "shape": [2, 2], "dtype": "float32"},
                {"name": "b", "shape": [2, 2], "dtype": "float32"},
            ],
            "outputs": [{"name": "out", "shape": [2, 2], "dtype": "float32"}],
        },
    )

    # ---- initial parameter values (so Rust starts from the same init) ---
    init_blob = {k: np.asarray(v).reshape(-1).tolist() for k, v in params.items()}
    with open(os.path.join(out_dir, "init_params.json"), "w") as f:
        json.dump(init_blob, f)
    print(f"  wrote init_params.json")

    manifest = {"artifacts": ["forward", "train_step", "train_step_kd", "smoke"], **common_meta}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  wrote manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--in-dim", type=int, default=1024)
    ap.add_argument("--hidden", default="1024,1024")
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--sp-o", type=float, default=0.5)
    ap.add_argument("--sp-i", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    hidden = tuple(int(h) for h in args.hidden.split(",") if h)
    print(f"AOT: lowering artifacts to {args.out}")
    export_artifacts(
        args.out,
        batch=args.batch,
        in_dim=args.in_dim,
        hidden=hidden,
        classes=args.classes,
        sp_o=args.sp_o,
        sp_i=args.sp_i,
        seed=args.seed,
    )


if __name__ == "__main__":
    main()
