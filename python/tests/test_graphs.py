"""Tests for the build-time graph generator (mirror of rust/src/graph)."""

import numpy as np
import pytest

from compile.graphs import (
    GraphSpec,
    Rbgp4Config,
    Rbgp4Mask,
    generate_ramanujan,
    is_ramanujan,
    lift2,
    lifts_for_sparsity,
    ramanujan_bound,
    sparse_biregular_by_lifts,
)

SEEDS = [0, 1, 2, 3, 4]


def degrees(adj: np.ndarray, nv: int):
    nu, dl = adj.shape
    counts = np.bincount(adj.reshape(-1), minlength=nv)
    assert (counts == counts[0]).all(), "not right-regular"
    return dl, int(counts[0])


def test_lift2_doubles_and_preserves_degrees():
    rng = np.random.default_rng(0)
    adj = np.tile(np.arange(4), (3, 1))  # K_{3,4}
    lifted = lift2(adj, rng)
    assert lifted.shape == (6, 4)
    dl, dr = degrees(lifted, 8)
    assert (dl, dr) == (4, 3)
    # Rows stay sorted and duplicate-free.
    for row in lifted:
        assert (np.diff(row) > 0).all()


@pytest.mark.parametrize("sp,k", [(0.0, 0), (0.5, 1), (0.75, 2), (0.875, 3), (0.9375, 4)])
def test_lifts_for_sparsity(sp, k):
    assert lifts_for_sparsity(sp) == k


def test_lifts_for_sparsity_rejects_nondyadic():
    with pytest.raises(ValueError):
        lifts_for_sparsity(0.6)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("m,n,sp", [(16, 16, 0.5), (32, 32, 0.75), (32, 128, 0.75), (64, 64, 0.875)])
def test_sparse_biregular_by_lifts(seed, m, n, sp):
    rng = np.random.default_rng(seed)
    adj = sparse_biregular_by_lifts(m, n, sp, rng)
    dl, dr = degrees(adj, n)
    assert dl == round((1 - sp) * n)
    assert dr == round((1 - sp) * m)
    assert 1.0 - adj.size / (m * n) == pytest.approx(sp)


def test_ramanujan_bound_values():
    assert ramanujan_bound(1, 1) == 0.0
    assert ramanujan_bound(4, 4) == pytest.approx(2 * np.sqrt(3))


@pytest.mark.parametrize("seed", SEEDS)
def test_generate_ramanujan_certifies(seed):
    rng = np.random.default_rng(seed)
    adj = generate_ramanujan(32, 32, 0.75, rng)
    assert is_ramanujan(adj, 32)


def test_complete_graph_is_ramanujan():
    rng = np.random.default_rng(0)
    adj = generate_ramanujan(8, 4, 0.0, rng)
    assert adj.shape == (8, 4)
    assert is_ramanujan(adj, 4)


SMALL = Rbgp4Config(go=GraphSpec(4, 4, 0.5), gr=(2, 1), gi=GraphSpec(4, 4, 0.5), gb=(2, 2))


def test_config_arithmetic_matches_rust():
    c = SMALL
    assert (c.rows, c.cols) == (64, 32)
    assert (c.tile_m, c.tile_k) == (16, 8)
    assert (c.d_o, c.d_i) == (2, 2)
    assert c.tile_row_nnz == 4
    assert c.row_nnz == 8
    assert c.sparsity == pytest.approx(0.75)


@pytest.mark.parametrize("seed", SEEDS)
def test_mask_col_index_matches_brute_force(seed):
    """The compact column layout must equal the sorted non-zeros of the
    Kronecker-product mask — the contract shared with the Rust side."""
    mask = Rbgp4Mask.sample(SMALL, seed)
    c = mask.config
    dense = mask.dense()
    cols = mask.col_index()
    for u in range(c.rows):
        nz = np.flatnonzero(dense[u])
        assert nz.size == c.row_nnz
        np.testing.assert_array_equal(cols[u], nz)


@pytest.mark.parametrize("seed", SEEDS[:3])
def test_mask_dense_is_kronecker_product(seed):
    mask = Rbgp4Mask.sample(SMALL, seed)
    c = mask.config
    ba_o = np.zeros((c.go.nu, c.go.nv), np.float32)
    ba_o[np.arange(c.go.nu)[:, None], mask.adj_o] = 1
    ba_i = np.zeros((c.gi.nu, c.gi.nv), np.float32)
    ba_i[np.arange(c.gi.nu)[:, None], mask.adj_i] = 1
    ba_r = np.ones(c.gr, np.float32)
    ba_b = np.ones(c.gb, np.float32)
    kron = np.kron(np.kron(np.kron(ba_o, ba_r), ba_i), ba_b)
    np.testing.assert_array_equal(mask.dense(), kron)


def test_mask_json_roundtrip():
    mask = Rbgp4Mask.sample(SMALL, 7)
    back = Rbgp4Mask.from_json(mask.to_json())
    assert back.config == mask.config
    np.testing.assert_array_equal(back.adj_o, mask.adj_o)
    np.testing.assert_array_equal(back.adj_i, mask.adj_i)


def test_local_cols_sorted_in_range():
    mask = Rbgp4Mask.sample(SMALL, 9)
    lc = mask.local_cols()
    assert lc.shape == (4, 4)
    assert (lc >= 0).all() and (lc < SMALL.tile_k).all()
    assert (np.diff(lc, axis=1) > 0).all()
