"""L2 tests: model shapes, training dynamics, KD, pallas/ref agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.graphs import GraphSpec, Rbgp4Config


@pytest.fixture(scope="module")
def small_spec():
    # 128 -> 128 -> 128 -> 4, tiny RBGP4 layers (fast under interpret mode).
    cfg = Rbgp4Config(go=GraphSpec(4, 16, 0.5), gr=(4, 1), gi=GraphSpec(8, 8, 0.5), gb=(1, 1))
    assert cfg.rows == 128 and cfg.cols == 128
    masks = tuple(
        __import__("compile.graphs", fromlist=["Rbgp4Mask"]).Rbgp4Mask.sample(cfg, s)
        for s in (1, 2)
    )
    spec = M.ModelSpec(in_dim=128, classes=4, layer_configs=(cfg, cfg), masks=masks)
    spec.validate()
    return spec


def batch_for(spec, seed, b=16):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, spec.in_dim)).astype(np.float32))
    labels = rng.integers(0, spec.classes, size=b)
    y = jnp.asarray(np.eye(spec.classes, dtype=np.float32)[labels])
    return x, y


def test_default_spec_validates_and_sizes():
    spec = M.default_spec()
    assert spec.hidden_dims == [1024, 1024]
    assert spec.layer_configs[0].sparsity == pytest.approx(0.75)
    spec.validate()


def test_forward_shapes(small_spec):
    params = M.init_params(small_spec, 0)
    x, _ = batch_for(small_spec, 0)
    logits = M.forward(params, x, small_spec)
    assert logits.shape == (16, 4)
    assert bool(jnp.isfinite(logits).all())


def test_forward_pallas_matches_gather(small_spec):
    params = M.init_params(small_spec, 1)
    x, _ = batch_for(small_spec, 1)
    a = M.forward(params, x, small_spec)
    b = M.forward_pallas(params, x, small_spec)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_loss_positive_and_near_log_classes_at_init(small_spec):
    params = M.init_params(small_spec, 2)
    x, y = batch_for(small_spec, 2)
    loss = float(M.loss_fn(params, x, y, small_spec))
    assert 0.5 * np.log(4) < loss < 3.0 * np.log(4)


def test_train_step_decreases_loss(small_spec):
    """Overfit one fixed batch for 40 steps: loss must drop substantially."""
    params = M.init_params(small_spec, 3)
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    x, y = batch_for(small_spec, 3, b=32)
    step = jax.jit(lambda p, v, lr: M.train_step(p, v, x, y, lr, small_spec))
    first = None
    lr = jnp.float32(0.05)
    for _ in range(40):
        params, vel, loss = step(params, vel, lr)
        if first is None:
            first = float(loss)
    assert float(loss) < 0.5 * first, (first, float(loss))


def test_train_step_preserves_shapes_and_finiteness(small_spec):
    params = M.init_params(small_spec, 4)
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    x, y = batch_for(small_spec, 4)
    new_p, new_v, loss = M.train_step(params, vel, x, y, jnp.float32(0.1), small_spec)
    for k in params:
        assert new_p[k].shape == params[k].shape
        assert new_v[k].shape == params[k].shape
        assert bool(jnp.isfinite(new_p[k]).all())
    assert bool(jnp.isfinite(loss))


def test_kd_loss_interpolates(small_spec):
    params = M.init_params(small_spec, 5)
    x, y = batch_for(small_spec, 5)
    teacher = M.forward(params, x, small_spec)  # self-teacher
    ce = float(M.loss_fn(params, x, y, small_spec))
    kd0 = float(M.loss_fn(params, x, y, small_spec, teacher_logits=teacher, kd_alpha=0.0))
    assert kd0 == pytest.approx(ce, rel=1e-6)
    kd = float(M.loss_fn(params, x, y, small_spec, teacher_logits=teacher, kd_alpha=0.5))
    assert np.isfinite(kd)


def test_momentum_actually_accumulates(small_spec):
    params = M.init_params(small_spec, 6)
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    x, y = batch_for(small_spec, 6)
    _, v1, _ = M.train_step(params, vel, x, y, jnp.float32(0.01), small_spec)
    _, v2, _ = M.train_step(params, v1, x, y, jnp.float32(0.01), small_spec)
    # Second-step velocity magnitude grows (same batch, aligned grads).
    n1 = float(sum(jnp.sum(v * v) for v in v1.values()))
    n2 = float(sum(jnp.sum(v * v) for v in v2.values()))
    assert n2 > n1


def test_spec_validation_catches_mismatch():
    cfg = Rbgp4Config(go=GraphSpec(4, 16, 0.5), gr=(4, 1), gi=GraphSpec(8, 8, 0.5), gb=(1, 1))
    spec = M.ModelSpec(in_dim=64, classes=4, layer_configs=(cfg,))
    with pytest.raises(ValueError):
        spec.validate()
