"""L1 correctness: Pallas RBGP4MM vs the pure-jnp oracle.

The CORE correctness signal of the compile path. Randomized configuration
sweeps (hypothesis-style: seeds × config space drawn from small ranges)
compare the Pallas kernel, the differentiable gather reference, and the
dense-expansion oracle on identical compact inputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.graphs import GraphSpec, Rbgp4Config, Rbgp4Mask
from compile.kernels.ref import (
    expand_dense,
    masked_dense_matmul,
    rbgp4mm_dense_ref,
    rbgp4mm_gather_ref,
)
from compile.kernels.rbgp4mm import make_rbgp4mm, rbgp4mm_pallas, vmem_footprint


def feasible_sp(rng: np.random.Generator, nu: int, nv: int) -> float:
    """A dyadic sparsity reachable by 2-lifts on an (nu × nv) base shape:
    1 - 2^-k requires 2^k | nu and 2^k | nv."""
    options = [0.0]
    for k, sp in ((1, 0.5), (2, 0.75)):
        if nu % (1 << k) == 0 and nv % (1 << k) == 0:
            options.append(sp)
    return float(rng.choice(options))


def random_config(rng: np.random.Generator) -> Rbgp4Config:
    """Draw a small-but-varied feasible RBGP4 config."""
    go_u, go_v = int(rng.choice([2, 4, 8])), int(rng.choice([2, 4, 8]))
    gi_u, gi_v = int(rng.choice([2, 4])) * 2, int(rng.choice([2, 4])) * 2
    return Rbgp4Config(
        go=GraphSpec(go_u, go_v, feasible_sp(rng, go_u, go_v)),
        gr=(int(rng.choice([1, 2, 4])), int(rng.choice([1, 2]))),
        gi=GraphSpec(gi_u, gi_v, feasible_sp(rng, gi_u, gi_v)),
        gb=(int(rng.choice([1, 2])), int(rng.choice([1, 2]))),
    )


def make_case(cfg: Rbgp4Config, seed: int, n: int, dtype=jnp.float32):
    mask = Rbgp4Mask.sample(cfg, seed)
    rng = np.random.default_rng(seed + 1)
    data = jnp.asarray(rng.normal(size=(cfg.rows, cfg.row_nnz)), dtype=dtype)
    x = jnp.asarray(rng.normal(size=(cfg.cols, n)), dtype=dtype)
    return mask, data, x


@pytest.mark.parametrize("seed", range(12))
def test_pallas_matches_oracle_random_configs(seed):
    rng = np.random.default_rng(seed)
    cfg = random_config(rng)
    n = int(rng.choice([4, 8, 16, 32]))
    mask, data, x = make_case(cfg, seed, n)
    want = rbgp4mm_dense_ref(data, mask, x)
    got = make_rbgp4mm(mask)(data, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seed", range(12))
def test_gather_ref_matches_oracle_random_configs(seed):
    rng = np.random.default_rng(seed + 100)
    cfg = random_config(rng)
    n = int(rng.choice([4, 8, 16]))
    mask, data, x = make_case(cfg, seed, n)
    want = rbgp4mm_dense_ref(data, mask, x)
    got = rbgp4mm_gather_ref(
        data,
        x,
        jnp.asarray(mask.adj_o, jnp.int32),
        jnp.asarray(mask.local_cols(), jnp.int32),
        cfg,
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


PAPER_FIG1 = Rbgp4Config(
    go=GraphSpec(2, 2, 0.5), gr=(2, 1), gi=GraphSpec(2, 2, 0.5), gb=(2, 2)
)
TABLE2_SMALL = Rbgp4Config(
    go=GraphSpec(8, 32, 0.5), gr=(4, 1), gi=GraphSpec(32, 32, 0.5), gb=(1, 1)
)


@pytest.mark.parametrize("cfg", [PAPER_FIG1, TABLE2_SMALL], ids=["fig1", "table2-small"])
@pytest.mark.parametrize("n", [8, 64])
def test_pallas_paper_configs(cfg, n):
    mask, data, x = make_case(cfg, 42, n)
    want = rbgp4mm_dense_ref(data, mask, x)
    got = make_rbgp4mm(mask)(data, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pallas_n_not_multiple_of_256():
    # TN picker must find a valid divisor for awkward N.
    cfg = PAPER_FIG1
    mask, data, x = make_case(cfg, 3, 24)
    want = rbgp4mm_dense_ref(data, mask, x)
    got = make_rbgp4mm(mask)(data, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pallas_explicit_tn():
    cfg = PAPER_FIG1
    mask, data, x = make_case(cfg, 4, 32)
    got = make_rbgp4mm(mask, tn=16)(data, x)
    want = rbgp4mm_dense_ref(data, mask, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pallas_dense_config_equals_plain_matmul():
    cfg = Rbgp4Config(go=GraphSpec(2, 2, 0.0), gr=(2, 2), gi=GraphSpec(4, 4, 0.0), gb=(1, 1))
    mask, data, x = make_case(cfg, 5, 8)
    w = expand_dense(data, mask.col_index(), cfg.cols)
    np.testing.assert_allclose(
        make_rbgp4mm(mask)(data, x), w @ x, rtol=1e-5, atol=1e-5
    )


def test_expand_dense_respects_mask():
    mask, data, x = make_case(TABLE2_SMALL, 6, 4)
    w = expand_dense(data, mask.col_index(), mask.config.cols)
    dense_mask = mask.dense()
    assert np.all((np.asarray(w) != 0) <= (dense_mask != 0))
    # Every stored weight lands somewhere: nnz matches.
    assert (np.asarray(w) != 0).sum() == (np.asarray(data) != 0).sum()


def test_masked_dense_matmul_baseline():
    mask, data, x = make_case(PAPER_FIG1, 7, 8)
    w = expand_dense(data, mask.col_index(), mask.config.cols)
    got = masked_dense_matmul(w, jnp.asarray(mask.dense()), x)
    np.testing.assert_allclose(got, rbgp4mm_dense_ref(data, mask, x), rtol=1e-5, atol=1e-5)


def test_gather_ref_is_differentiable_and_grads_match_dense():
    """∂/∂data of the gather formulation == gathered ∂/∂W of dense matmul."""
    cfg = PAPER_FIG1
    mask, data, x = make_case(cfg, 8, 8)
    adj_o = jnp.asarray(mask.adj_o, jnp.int32)
    lc = jnp.asarray(mask.local_cols(), jnp.int32)
    col_index = mask.col_index()

    def loss_compact(d):
        return jnp.sum(rbgp4mm_gather_ref(d, x, adj_o, lc, cfg) ** 2)

    def loss_dense(wd):
        return jnp.sum((wd @ x) ** 2)

    g_compact = jax.grad(loss_compact)(data)
    w = expand_dense(data, col_index, cfg.cols)
    g_dense = jax.grad(loss_dense)(w)
    g_dense_gathered = np.asarray(g_dense)[np.arange(cfg.rows)[:, None], col_index]
    np.testing.assert_allclose(g_compact, g_dense_gathered, rtol=1e-4, atol=1e-4)


def test_pallas_accumulation_over_many_steps():
    # d_o > 2 exercises the accumulate-over-grid-axis path.
    cfg = Rbgp4Config(go=GraphSpec(2, 8, 0.5), gr=(1, 1), gi=GraphSpec(4, 4, 0.5), gb=(1, 1))
    mask, data, x = make_case(cfg, 9, 16)
    assert cfg.d_o == 4
    np.testing.assert_allclose(
        make_rbgp4mm(mask)(data, x),
        rbgp4mm_dense_ref(data, mask, x),
        rtol=1e-5,
        atol=1e-5,
    )


def test_vmem_footprint_reporting():
    fp = vmem_footprint(TABLE2_SMALL, tn=128)
    assert fp["fits_16mib_vmem"]
    assert fp["total_bytes"] > 0
    assert fp["matmul_shape"] == (4, 16, 128)
    assert 0 < fp["mxu_util_proxy"] <= 1


def test_pallas_rejects_bad_shapes():
    mask, data, x = make_case(PAPER_FIG1, 10, 8)
    with pytest.raises(AssertionError):
        rbgp4mm_pallas(
            data[:, :-1],
            x,
            jnp.asarray(mask.adj_o.reshape(-1), jnp.int32),
            jnp.asarray(mask.local_cols(), jnp.int32),
            mask.config,
        )
