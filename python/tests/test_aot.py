"""AOT export tests: artifacts exist, metadata is consistent, HLO is clean."""

import json
import os

import pytest

from compile.aot import export_artifacts, to_hlo_text


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = export_artifacts(
        str(out), batch=8, in_dim=128, hidden=(128,), classes=4, sp_o=0.5, sp_i=0.5, seed=0
    )
    return str(out), manifest


def test_all_artifacts_written(exported):
    out, manifest = exported
    for name in manifest["artifacts"]:
        assert os.path.exists(os.path.join(out, f"{name}.hlo.txt")), name
        assert os.path.exists(os.path.join(out, f"{name}.json")), name
    assert os.path.exists(os.path.join(out, "manifest.json"))
    assert os.path.exists(os.path.join(out, "init_params.json"))


def test_hlo_text_is_parseable_hlo(exported):
    out, manifest = exported
    for name in manifest["artifacts"]:
        text = open(os.path.join(out, f"{name}.hlo.txt")).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # No Mosaic custom calls may leak into CPU artifacts.
        assert "custom-call" not in text, name
        # No elided large constants: "..." in the text means the adjacency
        # arrays were truncated and the executable computes garbage.
        assert "..." not in text, name


def test_metadata_signature_consistency(exported):
    out, _ = exported
    meta = json.load(open(os.path.join(out, "train_step.json")))
    order = meta["param_order"]
    inputs = [i["name"] for i in meta["inputs"]]
    # params..., velocities..., x, y, lr
    assert inputs == order + [f"v_{k}" for k in order] + ["x", "y", "lr"]
    outputs = [o["name"] for o in meta["outputs"]]
    assert outputs == [f"new_{k}" for k in order] + [f"new_v_{k}" for k in order] + ["loss"]
    # Shapes of params equal shapes of their velocity/new counterparts.
    shapes = {i["name"]: i["shape"] for i in meta["inputs"]}
    for k in order:
        assert shapes[k] == shapes[f"v_{k}"]


def test_forward_metadata_has_masks(exported):
    out, _ = exported
    meta = json.load(open(os.path.join(out, "forward.json")))
    assert len(meta["masks"]) == len(meta["layer_configs"]) == 1
    mask = meta["masks"][0]
    cfg = meta["layer_configs"][0]
    assert len(mask["adj_o"]) == cfg["go_nu"] * round((1 - cfg["go_sp"]) * cfg["go_nv"])


def test_init_params_match_declared_shapes(exported):
    out, _ = exported
    meta = json.load(open(os.path.join(out, "forward.json")))
    init = json.load(open(os.path.join(out, "init_params.json")))
    shapes = {i["name"]: i["shape"] for i in meta["inputs"]}
    for k, flat in init.items():
        want = 1
        for d in shapes[k]:
            want *= d
        assert len(flat) == want, k


def test_kd_artifact_has_teacher_input(exported):
    out, _ = exported
    meta = json.load(open(os.path.join(out, "train_step_kd.json")))
    names = [i["name"] for i in meta["inputs"]]
    assert "teacher_logits" in names


def test_to_hlo_text_smoke():
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda a: (a + 1.0,)).lower(jnp.zeros((2,), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
